//! Property-based tests (via the in-tree `prop` mini-framework) over the
//! substrate invariants: packed bit algebra, comparator probabilities,
//! JSON round-trips, parser robustness under corruption, LIF dynamics.

use ssa_repro::anytime::{margin_of, ExitPolicy};
use ssa_repro::attention::lif::LifLayer;
use ssa_repro::attention::model::{image_seed, Arch, ModelGeometry, NativeModel};
use ssa_repro::attention::ssa::bern_compare;
use ssa_repro::config::{LifConfig, PrngSharing};
use ssa_repro::prop::{check, ensure, Gen};
use ssa_repro::runtime::weights::test_support::build_weights;
use ssa_repro::runtime::{Dataset, Weights};
use ssa_repro::tensor::{spike_matmul, spike_matmul_into, Tensor};
use ssa_repro::util::bitpack::BitMatrix;
use ssa_repro::util::json::Json;
use ssa_repro::util::simd;

#[test]
fn prop_and_popcount_matches_naive() {
    check("and_popcount == naive", 300, |g| {
        let cols = g.usize_in(1, 300);
        let ra = g.f64_01();
        let rb = g.f64_01();
        let a = g.spikes(cols, ra);
        let b = g.spikes(cols, rb);
        let am = BitMatrix::from_f01(1, cols, &a);
        let bm = BitMatrix::from_f01(1, cols, &b);
        let naive: u32 = a.iter().zip(&b).map(|(x, y)| (*x as u32) & (*y as u32)).sum();
        ensure(
            am.and_popcount(0, &bm, 0) == naive,
            format!("cols={cols}: {} != {naive}", am.and_popcount(0, &bm, 0)),
        )
    });
}

#[test]
fn prop_simd_and_popcount_matches_scalar_kernel() {
    // The SIMD dispatch contract: whatever kernel the CPU resolves to,
    // the result is the scalar reference's, bit for bit, over arbitrary
    // slice lengths (covering the wide kernels' ragged tails and their
    // below-minimum-length fallback) and densities from dead-silent to
    // saturated.
    check("simd::and_popcount == scalar kernel", 400, |g| {
        let words = g.usize_in(0, 40);
        let fill = g.usize_in(0, 3);
        let word = |g: &mut Gen| match fill {
            0 => 0u64,
            1 => u64::MAX,
            _ => g.u64(),
        };
        let a: Vec<u64> = (0..words).map(|_| word(g)).collect();
        let b: Vec<u64> = (0..words).map(|_| word(g)).collect();
        let scalar = simd::and_popcount_scalar(&a, &b);
        let dispatched = simd::and_popcount(&a, &b);
        ensure(
            dispatched == scalar,
            format!(
                "words={words} fill={fill}: {} kernel returned {dispatched}, scalar {scalar}",
                simd::kernel_name()
            ),
        )
    });
}

#[test]
fn prop_blockwise_transpose_matches_per_bit_reference() {
    // The word-level 64x64 block transpose behind `transpose_into` must
    // agree with the naive per-bit definition over arbitrary shapes —
    // including both dimensions ragged against the 64-bit word grid.
    check("blockwise transpose == per-bit reference", 120, |g| {
        let rows = g.usize_in(1, 150);
        let cols = g.usize_in(1, 150);
        let rate = [0.0, 0.05, 0.5, 1.0][g.usize_in(0, 3)];
        let m = BitMatrix::from_f01(rows, cols, &g.spikes(rows * cols, rate));
        let t = m.transpose();
        for r in 0..rows {
            for c in 0..cols {
                ensure(
                    m.get(r, c) == t.get(c, r),
                    format!("{rows}x{cols} rate={rate}: bit ({r},{c}) lost in transpose"),
                )?;
            }
        }
        ensure(t.transpose() == m, "transpose not involutive")
    });
}

#[test]
fn prop_infer_rows_bit_identical_across_intra_thread_counts() {
    // The intra-request parallelism contract end to end: splitting one
    // request across batch rows and attention heads must reproduce the
    // sequential logits bit for bit, for any geometry, arch, batch size,
    // and thread count (including counts exceeding rows x heads).
    check("infer_rows == sequential for any intra-threads", 12, |g| {
        let arch = [Arch::Ssa, Arch::Spikformer, Arch::Ann][g.usize_in(0, 2)];
        let (mut m, img) = random_tiny_model(g, arch);
        let px = img.len();
        let batch = g.usize_in(1, 4);
        let images: Vec<f32> = (0..batch * px).map(|i| img[i % px] * 0.9).collect();
        let seeds: Vec<u64> = (0..batch).map(|i| image_seed(g.u64() as u32, i)).collect();
        let want =
            m.infer_rows(&images, batch, &seeds).map_err(|e| format!("sequential: {e:#}"))?;
        for threads in [2, 3, g.usize_in(4, 9)] {
            m.set_intra_threads(threads);
            let got = m
                .infer_rows(&images, batch, &seeds)
                .map_err(|e| format!("{threads}t: {e:#}"))?;
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                ensure(
                    a.to_bits() == b.to_bits(),
                    format!("{arch:?} batch={batch} threads={threads}: logit {i}: {a} != {b}"),
                )?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_bitmatrix_roundtrip_and_transpose() {
    check("BitMatrix f01 roundtrip + transpose involution", 200, |g| {
        let rows = g.usize_in(1, 20);
        let cols = g.usize_in(1, 150);
        let vals = g.spikes(rows * cols, 0.5);
        let m = BitMatrix::from_f01(rows, cols, &vals);
        ensure(m.to_f01() == vals, "roundtrip failed")?;
        ensure(m.transpose().transpose() == m, "transpose not involutive")
    });
}

#[test]
fn prop_spike_matmul_bit_identical_to_dense_reference() {
    // The accumulation-order contract of the spike-domain GEMM: for any
    // geometry (including non-multiple-of-64 inner dims, i.e. partially
    // filled last words) and any sparsity — the paper's spike rates span
    // dead-silent to saturated — the packed trailing_zeros walk must
    // reproduce the dense {0,1} x matmul result to the exact f32 bit.
    check("spike_matmul == dense f01 matmul (bitwise)", 200, |g| {
        let m = g.usize_in(1, 20);
        let heads = g.usize_in(1, 4);
        let d_head = g.usize_in(1, 48);
        let k = heads * d_head; // multi-head-shaped inner dims too
        let n = g.usize_in(1, 24);
        let sparsity = [0.0, 0.1, 0.5, 1.0][g.usize_in(0, 3)];
        let s = g.spikes(m * k, sparsity);
        let bits = BitMatrix::from_f01(m, k, &s);
        let w = Tensor::from_vec(
            &[k, n],
            (0..k * n).map(|_| g.f32_01() * 4.0 - 2.0).collect(),
        );
        let dense = Tensor::from_vec(&[m, k], s).matmul(&w);
        let packed = spike_matmul(&bits, &w);
        for (idx, (a, b)) in dense.data().iter().zip(packed.data()).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("m={m} k={k} n={n} rate={sparsity}: elem {idx}: {a} != {b}"),
            )?;
        }
        // per-head column slabs see the same contract (the layer hot path
        // slices [m, k] into `heads` slabs of d_head columns)
        let h = g.usize_in(0, heads - 1);
        let slab = bits.col_slice(h * d_head, d_head);
        let wh = Tensor::from_vec(
            &[d_head, n],
            (0..d_head * n).map(|_| g.f32_01() * 4.0 - 2.0).collect(),
        );
        let want = Tensor::from_vec(&[m, d_head], slab.to_f01()).matmul(&wh);
        let mut got = Tensor::full(&[m, n], f32::NAN); // dirty scratch
        spike_matmul_into(&slab, &wh, &mut got);
        for (idx, (a, b)) in want.data().iter().zip(got.data()).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("head slab h={h}: elem {idx}: {a} != {b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_bern_compare_probability_bound() {
    // P(spike) = ceil/floor approximation of count/m with error <= m/2^16,
    // and monotone in count.
    check("bern_compare probability", 40, |g| {
        let m = g.usize_in(1, 300) as u32;
        let count = g.usize_in(0, m as usize) as u32;
        let hits = (0..=u16::MAX).filter(|&u| bern_compare(u, count, m)).count();
        let p = hits as f64 / 65536.0;
        let target = count as f64 / m as f64;
        ensure(
            (p - target).abs() <= m as f64 / 65536.0 + 1e-12,
            format!("m={m} count={count}: p={p} target={target}"),
        )?;
        if count < m {
            let hits_next =
                (0..=u16::MAX).filter(|&u| bern_compare(u, count + 1, m)).count();
            ensure(hits_next >= hits, "not monotone in count")?;
        }
        Ok(())
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(g: &mut Gen, depth: usize) -> Json {
        match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(g.bool(0.5)),
            2 => Json::Num((g.usize_in(0, 1_000_000) as f64) - 500_000.0),
            3 => Json::Str(
                (0..g.usize_in(0, 12))
                    .map(|_| char::from_u32(g.usize_in(32, 126) as u32).unwrap())
                    .collect(),
            ),
            4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| random_json(g, depth - 1)).collect()),
            _ => Json::Obj(
                (0..g.usize_in(0, 4))
                    .map(|i| (format!("k{i}"), random_json(g, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json print->parse roundtrip", 300, |g| {
        let v = random_json(g, 3);
        let text = v.to_string();
        let re = Json::parse(&text).map_err(|e| format!("reparse failed: {e} on {text}"))?;
        ensure(re == v, format!("roundtrip mismatch: {text}"))
    });
}

#[test]
fn prop_parsers_never_panic_on_corruption() {
    // Corrupt/truncate valid files arbitrarily: parsers must return Err,
    // not panic (failure injection for the artifact loaders).
    let mut weights_bytes = Vec::new();
    {
        // magic, version, count=1, "w" [2,2] data
        weights_bytes.extend(0x5353_4157u32.to_le_bytes());
        weights_bytes.extend(1u32.to_le_bytes());
        weights_bytes.extend(1u32.to_le_bytes());
        weights_bytes.extend(1u32.to_le_bytes());
        weights_bytes.push(b'w');
        weights_bytes.extend(2u32.to_le_bytes());
        weights_bytes.extend(2u32.to_le_bytes());
        weights_bytes.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            weights_bytes.extend(v.to_le_bytes());
        }
    }
    check("weights/dataset parsers survive corruption", 500, |g| {
        let mut buf = weights_bytes.clone();
        match g.usize_in(0, 2) {
            0 => {
                let cut = g.usize_in(0, buf.len());
                buf.truncate(cut);
            }
            1 => {
                let idx = g.usize_in(0, buf.len() - 1);
                buf[idx] ^= (g.u64() as u8) | 1;
            }
            _ => {
                let idx = g.usize_in(0, buf.len() - 1);
                buf.splice(idx..idx, std::iter::repeat(g.u64() as u8).take(g.usize_in(1, 9)));
            }
        }
        let _ = Weights::parse(&buf); // must not panic
        let _ = Dataset::parse(&buf);
        Ok(())
    });
}

#[test]
fn prop_lif_membrane_bounded_under_bounded_input() {
    // With |I| <= c and leak beta < 1, the membrane stays bounded by
    // c/(1-beta) + theta — stability of the neuron model.
    check("LIF membrane bounded", 100, |g| {
        let beta = 0.5 + 0.4 * g.f32_01();
        let theta = 0.5 + g.f32_01();
        let c = 2.0 * g.f32_01();
        let mut layer = LifLayer::new(1, 4, LifConfig { beta, theta });
        let bound = c / (1.0 - beta) + theta + 1e-3;
        for _ in 0..200 {
            let cur = Tensor::from_vec(
                &[1, 4],
                (0..4).map(|_| (g.f32_01() * 2.0 - 1.0) * c).collect(),
            );
            layer.step(&cur);
            for &v in layer.membrane() {
                ensure(
                    v.abs() <= bound,
                    format!("|v|={} > bound={bound} (beta={beta} theta={theta})", v.abs()),
                )?;
            }
        }
        Ok(())
    });
}

/// Build a random-but-valid tiny model: geometry, weights, and one image.
fn random_tiny_model(g: &mut Gen, arch: Arch) -> (NativeModel, Vec<f32>) {
    let patch_size = [2usize, 4][g.usize_in(0, 1)];
    let grid = g.usize_in(1, 3);
    let n_heads = g.usize_in(1, 2);
    let d_head = g.usize_in(4, 10);
    let geo = ModelGeometry {
        image_size: patch_size * grid,
        patch_size,
        n_tokens: grid * grid,
        patch_dim: patch_size * patch_size,
        d_model: n_heads * d_head,
        n_heads,
        d_head,
        d_mlp: g.usize_in(8, 24),
        n_layers: g.usize_in(1, 2),
        n_classes: g.usize_in(2, 5),
        time_steps: g.usize_in(2, 6),
        lif: LifConfig::default(),
        prng_sharing: PrngSharing::PerRow,
        spikformer_scale: 0.25,
    };
    let w = build_weights(
        geo.patch_dim,
        geo.d_model,
        geo.n_tokens,
        geo.d_mlp,
        geo.n_layers,
        geo.n_classes,
        g.u64(),
    );
    let px = geo.image_size * geo.image_size;
    let img: Vec<f32> = (0..px).map(|_| g.f32_01()).collect();
    let m = NativeModel::from_weights(geo, arch, &w).expect("synthetic geometry is valid");
    (m, img)
}

#[test]
fn prop_anytime_full_policy_bit_identical_to_exact_inference() {
    // The regression spine of the anytime subsystem: for ANY geometry,
    // arch, seed, and input, `ExitPolicy::Full` must reproduce the exact
    // inference path to the f32 bit and run every step.
    check("ExitPolicy::Full == infer_image (bitwise)", 30, |g| {
        let arch = [Arch::Ssa, Arch::Spikformer, Arch::Ann][g.usize_in(0, 2)];
        let (m, img) = random_tiny_model(g, arch);
        let seed = g.u64();
        let exact = m.infer_image(&img, seed).map_err(|e| format!("infer_image: {e:#}"))?;
        let out = m
            .infer_image_anytime(&img, seed, &ExitPolicy::Full)
            .map_err(|e| format!("infer_image_anytime: {e:#}"))?;
        for (i, (a, b)) in exact.iter().zip(&out.logits).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("{arch:?} seed={seed}: logit {i}: {a} != {b}"),
            )?;
        }
        let want_steps = if arch == Arch::Ann { 1 } else { m.geometry().time_steps };
        ensure(
            out.steps_used == want_steps,
            format!("{arch:?}: steps_used {} != {want_steps}", out.steps_used),
        )?;
        ensure(
            out.margin.to_bits() == margin_of(&out.logits).to_bits(),
            "reported margin must be the decoded logit margin",
        )
    });
}

#[test]
fn prop_anytime_infinite_margin_threshold_never_exits_early() {
    // Decoded margins are clamped finite (degenerate cases report
    // f32::MAX), so an infinite threshold can never fire: the policy
    // must run all T steps and land exactly on the exact-path logits.
    check("margin:inf runs full T", 20, |g| {
        let arch = [Arch::Ssa, Arch::Spikformer][g.usize_in(0, 1)];
        let (m, img) = random_tiny_model(g, arch);
        let seed = g.u64();
        let policy = ExitPolicy::Margin { threshold: f32::INFINITY, min_steps: 1 };
        let out = m
            .infer_image_anytime(&img, seed, &policy)
            .map_err(|e| format!("infer_image_anytime: {e:#}"))?;
        ensure(
            out.steps_used == m.geometry().time_steps,
            format!("{arch:?}: exited at step {} < T", out.steps_used),
        )?;
        let exact = m.infer_image(&img, seed).map_err(|e| format!("infer_image: {e:#}"))?;
        for (i, (a, b)) in exact.iter().zip(&out.logits).enumerate() {
            ensure(
                a.to_bits() == b.to_bits(),
                format!("{arch:?} seed={seed}: logit {i}: {a} != {b}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_tensor_matmul_distributes_over_add() {
    check("(A+B)C == AC + BC", 100, |g| {
        let (m, k, n) = (g.usize_in(1, 6), g.usize_in(1, 6), g.usize_in(1, 6));
        let rand_t = |g: &mut Gen, r: usize, c: usize| {
            Tensor::from_vec(&[r, c], (0..r * c).map(|_| g.f32_01() * 2.0 - 1.0).collect())
        };
        let a = rand_t(g, m, k);
        let b = rand_t(g, m, k);
        let c = rand_t(g, k, n);
        let lhs = a.add(&b).matmul(&c);
        let rhs = a.matmul(&c).add(&b.matmul(&c));
        ensure(lhs.max_abs_diff(&rhs) < 1e-4, "distributivity violated")
    });
}
