//! Native-backend integration: the coordinator serves classify requests
//! end-to-end from a synthesized artifacts directory containing ONLY
//! `manifest.json` + weights files — no HLO artifacts, no PJRT client,
//! no Python.  Also pins the two load-bearing native-model properties:
//!
//! * bit-exactness — the multi-head SSA layer's per-head `S^t` / `Attn^t`
//!   bits equal standalone `SsaAttention::step` runs under the shared
//!   `seeds::head` PRNG contract;
//! * convergence — rate-decoded SSA attention approaches the
//!   `ssa_expectation` reference as `time_steps` grows (the E4 property,
//!   here exercised through the native backend's building block).

use std::path::PathBuf;
use std::time::Duration;

use ssa_repro::attention::block::{head_config, MultiHeadSsa};
use ssa_repro::attention::ssa::{seeds, ssa_expectation, SsaAttention};
use ssa_repro::attention::stochastic::encode_frame;
use ssa_repro::config::{AttnConfig, BackendKind, PrngSharing};
use ssa_repro::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, SeedPolicy, Target,
};
use ssa_repro::runtime::weights::test_support::build_weight_bytes;
use ssa_repro::runtime::{InferenceBackend, Manifest, NativeBackend};
use ssa_repro::tensor::Tensor;
use ssa_repro::util::rng::Xoshiro256;

// --- synthetic artifacts -----------------------------------------------------

/// Tiny servable geometry: 8x8 images, 4x4 patches -> N=4 tokens, D=16,
/// H=2, M=32, 1 encoder layer, 3 classes.
const IMAGE: usize = 8;
const PX: usize = IMAGE * IMAGE;

fn manifest_json() -> String {
    let variant = |name: &str, arch: &str, t: usize, batch: usize| {
        format!(
            r#"{{
            "name": "{name}", "arch": "{arch}", "time_steps": {t}, "batch": {batch},
            "hlo": "{name}.hlo.txt", "weights": "weights_{arch}.bin",
            "param_names": [],
            "inputs": [
                {{"name": "images", "shape": [{batch}, {IMAGE}, {IMAGE}], "dtype": "f32"}},
                {{"name": "seed", "shape": [], "dtype": "u32"}}
            ],
            "output": {{"shape": [{batch}, 3], "dtype": "f32"}}
        }}"#
        )
    };
    format!(
        r#"{{
        "version": 1, "image_size": {IMAGE}, "patch_size": 4, "n_classes": 3,
        "golden_seed": 42,
        "model": {{"n_heads": 2, "lif_beta": 0.9, "lif_theta": 1.0, "prng_sharing": "per-row"}},
        "dataset": {{"test": "dataset_test.bin", "n": 0}},
        "variants": [{}, {}, {}]
    }}"#,
        variant("ssa_t4", "ssa", 4, 4),
        variant("spikformer_t4", "spikformer", 4, 2),
        variant("ann", "ann", 0, 2)
    )
}

/// Write manifest + weights (and nothing else — in particular no `.hlo`
/// files) into a fresh per-test directory.
fn synth_artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ssa-native-it-{}-{tag}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("mkdir artifacts");
    std::fs::write(dir.join("manifest.json"), manifest_json()).expect("write manifest");
    let weights = build_weight_bytes(16, 16, 4, 32, 1, 3, 0xBEEF);
    for arch in ["ssa", "spikformer", "ann"] {
        std::fs::write(dir.join(format!("weights_{arch}.bin")), &weights)
            .expect("write weights");
    }
    assert!(
        std::fs::read_dir(&dir).unwrap().all(|e| {
            let n = e.unwrap().file_name().to_string_lossy().to_string();
            !n.ends_with(".hlo.txt")
        }),
        "the native artifacts dir must carry no XLA artifacts"
    );
    dir
}

fn start(tag: &str, max_batch: usize, delay_ms: u64, seed0: u32) -> Coordinator {
    let mut cfg = CoordinatorConfig::new(synth_artifacts(tag))
        .with_backend(BackendKind::Native);
    cfg.policy = BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) };
    cfg.preload = vec!["ssa_t4".into()];
    cfg.initial_batch_seed = seed0;
    Coordinator::start(cfg).expect("native coordinator must start without XLA artifacts")
}

fn image(fill: f32) -> Vec<f32> {
    (0..PX).map(|i| (fill + (i % 7) as f32 / 14.0).clamp(0.0, 1.0)).collect()
}

// --- end-to-end serving ------------------------------------------------------

#[test]
fn native_coordinator_serves_all_archs_end_to_end() {
    let coord = start("all-archs", 4, 5, 1);
    for target in [Target::ssa(4), Target::spikformer(4), Target::ann()] {
        let resp = coord
            .classify(target.clone(), image(0.4), SeedPolicy::Fixed(7))
            .expect("classify");
        assert_eq!(resp.logits.len(), 3, "target {target:?}");
        assert!(resp.class < 3);
        assert!(resp.logits.iter().all(|v| v.is_finite()));
    }
    let report = coord.metrics_report();
    assert!(report.contains("ssa_t4"), "metrics must track the native batches");
    coord.shutdown();
}

#[test]
fn ragged_image_buffers_are_rejected_with_a_clear_error() {
    // Regression: row derivation used to floor `len / px`, silently
    // truncating ragged buffers; it must fail fast with a clear message.
    let dir = synth_artifacts("ragged");
    let manifest = Manifest::load(&dir).expect("manifest");
    let variant = manifest
        .variants
        .iter()
        .find(|v| v.name == "ssa_t4")
        .expect("ssa_t4 variant");
    let loaded = NativeBackend::new().load(&manifest, variant).expect("load");
    for bad_len in [1usize, PX - 1, PX + 1, 2 * PX + 7] {
        let buf = vec![0.5f32; bad_len];
        let err = loaded.infer(&buf, 1).expect_err("ragged buffer must be rejected");
        assert!(
            format!("{err:#}").contains("whole number"),
            "bad_len={bad_len}: error must explain the raggedness, got: {err:#}"
        );
    }
    // exact multiples up to the variant batch still serve
    let two = vec![0.5f32; 2 * PX];
    let logits = loaded.infer(&two, 1).expect("2 whole images");
    assert_eq!(logits.len(), 6);
    // and oversized whole-image buffers are still rejected (batch = 4)
    let five = vec![0.5f32; 5 * PX];
    assert!(loaded.infer(&five, 1).is_err());
}

#[test]
fn native_fixed_seed_is_reproducible() {
    let coord = start("fixed-seed", 1, 1, 1);
    let a = coord.classify(Target::ssa(4), image(0.5), SeedPolicy::Fixed(99)).unwrap();
    let b = coord.classify(Target::ssa(4), image(0.5), SeedPolicy::Fixed(99)).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.seed, 99);
    let c = coord.classify(Target::ssa(4), image(0.5), SeedPolicy::Fixed(100)).unwrap();
    assert_ne!(a.logits, c.logits, "different fixed seed must change SSA logits");
    coord.shutdown();
}

#[test]
fn per_coordinator_batch_seed_makes_runs_deterministic() {
    // Two coordinators with the same initial batch seed must assign the
    // same PerBatch seeds in the same order — the counter is per-instance
    // state now, not a process-global atomic.
    let run = |tag: &str| -> (u32, Vec<f32>) {
        let coord = start(tag, 1, 1, 0x5EED_0001);
        let r = coord.classify(Target::ssa(4), image(0.3), SeedPolicy::PerBatch).unwrap();
        coord.shutdown();
        (r.seed, r.logits)
    };
    let (seed_a, logits_a) = run("det-a");
    let (seed_b, logits_b) = run("det-b");
    assert_eq!(seed_a, seed_b, "same initial counter => same assigned seed");
    assert_eq!(logits_a, logits_b);
}

#[test]
fn mixed_seed_policy_batches_report_their_own_seeds() {
    let coord = start("mixed-policy", 8, 40, 500);
    // queue a PerBatch head followed by Fixed requests before the window
    // closes: the router must split them, so the Fixed callers get their
    // exact seed back instead of the head request's policy.
    let rx_pb = coord.submit(Target::ssa(4), image(0.2), SeedPolicy::PerBatch).unwrap();
    let rx_f1 = coord.submit(Target::ssa(4), image(0.2), SeedPolicy::Fixed(1234)).unwrap();
    let rx_f2 = coord.submit(Target::ssa(4), image(0.6), SeedPolicy::Fixed(1234)).unwrap();
    let pb = rx_pb.recv().unwrap();
    let f1 = rx_f1.recv().unwrap();
    let f2 = rx_f2.recv().unwrap();
    assert_eq!(pb.seed, 500, "PerBatch head takes the coordinator counter");
    assert_eq!(f1.seed, 1234);
    assert_eq!(f2.seed, 1234);
    assert_eq!(f1.batch_size, 2, "the two Fixed(1234) requests batch together");
    coord.shutdown();
}

#[test]
fn ensemble_policy_serves_on_native_backend() {
    let coord = start("ensemble", 1, 1, 40);
    let r = coord.classify(Target::ssa(4), image(0.5), SeedPolicy::Ensemble(4)).unwrap();
    assert_eq!(r.logits.len(), 3);
    assert_eq!(r.seed, 40, "ensemble reports its first seed");
    coord.shutdown();
}

// --- PRNG seed contract (acceptance: per-head bits match SsaAttention) ------

#[test]
fn native_multihead_bits_match_standalone_ssa_attention() {
    let cfg = AttnConfig { n_tokens: 8, d_model: 32, n_heads: 4, d_head: 8, time_steps: 10 };
    let base = 0x0DDB_A11;
    let layer = 1;
    for sharing in [PrngSharing::Independent, PrngSharing::PerRow, PrngSharing::Global] {
        let mut mh = MultiHeadSsa::new(cfg, sharing, base, layer);
        let mut standalone: Vec<SsaAttention> = (0..cfg.n_heads)
            .map(|h| SsaAttention::new(head_config(&cfg), sharing, seeds::head(base, layer, h)))
            .collect();
        let mut rng = Xoshiro256::new(777);
        for _t in 0..6 {
            let mk = |rng: &mut Xoshiro256, rate: f32| {
                encode_frame(&Tensor::full(&[8, 32], rate), rng)
            };
            let q = mk(&mut rng, 0.5);
            let k = mk(&mut rng, 0.4);
            let v = mk(&mut rng, 0.6);
            let out = mh.step(&q, &k, &v);
            for (h, ssa) in standalone.iter_mut().enumerate() {
                let expect = ssa.step(
                    &q.col_slice(h * cfg.d_head, cfg.d_head),
                    &k.col_slice(h * cfg.d_head, cfg.d_head),
                    &v.col_slice(h * cfg.d_head, cfg.d_head),
                );
                assert_eq!(
                    out.per_head[h].s, expect.s,
                    "{sharing:?} head {h}: S^t bits diverged from the seed contract"
                );
                assert_eq!(
                    out.per_head[h].attn, expect.attn,
                    "{sharing:?} head {h}: Attn^t bits diverged from the seed contract"
                );
            }
        }
    }
}

// --- convergence property (rate decode -> ssa_expectation) ------------------

/// Mean absolute error of the rate-decoded multi-head SSA output against
/// the per-head `ssa_expectation` reference, after `t_steps` steps on
/// fixed spike inputs.
fn multihead_rate_mae(cfg: &AttnConfig, t_steps: usize, seed: u64) -> f64 {
    let mut rng = Xoshiro256::new(9000);
    let q = encode_frame(&Tensor::full(&[cfg.n_tokens, cfg.d_model], 0.55), &mut rng);
    let k = encode_frame(&Tensor::full(&[cfg.n_tokens, cfg.d_model], 0.45), &mut rng);
    let v = encode_frame(&Tensor::full(&[cfg.n_tokens, cfg.d_model], 0.6), &mut rng);

    let d_k = cfg.d_head;
    let expect: Vec<Vec<f64>> = (0..cfg.n_heads)
        .map(|h| {
            ssa_expectation(
                &q.col_slice(h * d_k, d_k),
                &k.col_slice(h * d_k, d_k),
                &v.col_slice(h * d_k, d_k),
            )
        })
        .collect();

    let mut mh = MultiHeadSsa::new(*cfg, PrngSharing::Independent, seed, 0);
    let mut counts = vec![vec![0u64; cfg.n_tokens * d_k]; cfg.n_heads];
    for _ in 0..t_steps {
        let out = mh.step(&q, &k, &v);
        for (h, o) in out.per_head.iter().enumerate() {
            for i in 0..cfg.n_tokens {
                for d in 0..d_k {
                    if o.attn.get(i, d) {
                        counts[h][i * d_k + d] += 1;
                    }
                }
            }
        }
    }
    let mut err = 0.0;
    let mut n = 0usize;
    for h in 0..cfg.n_heads {
        for (c, e) in counts[h].iter().zip(&expect[h]) {
            err += (*c as f64 / t_steps as f64 - e).abs();
            n += 1;
        }
    }
    err / n as f64
}

#[test]
fn rate_decoded_attention_converges_to_ssa_expectation() {
    let cfg = AttnConfig { n_tokens: 8, d_model: 32, n_heads: 2, d_head: 16, time_steps: 10 };
    let short = multihead_rate_mae(&cfg, 25, 31);
    let long = multihead_rate_mae(&cfg, 2500, 31);
    // Monte-Carlo error shrinks ~1/sqrt(T): a 100x step increase must cut
    // the MAE decisively, and the long run must sit near the reference.
    assert!(
        long < short * 0.5,
        "MAE did not shrink with T: short(T=25)={short:.4} long(T=2500)={long:.4}"
    );
    assert!(long < 0.02, "long-run MAE too large: {long:.4}");
}
