//! Coordinator integration (needs `make artifacts`): batching under load,
//! mixed-target routing, seed policies, error paths, graceful shutdown.

use std::path::PathBuf;
use std::time::Duration;

use ssa_repro::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, SeedPolicy, ServeError, Target,
};
use ssa_repro::runtime::Dataset;

fn start(max_batch: usize, delay_ms: u64) -> Option<(Coordinator, Dataset)> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("integration_coordinator: artifacts/ missing (skipped)");
        return None;
    }
    let mut cfg = CoordinatorConfig::new(dir);
    cfg.policy =
        BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) };
    cfg.preload = vec!["ssa_t4".into()];
    let coord = Coordinator::start(cfg).expect("coordinator");
    let ds = Dataset::load(&coord.manifest().dataset_test).expect("dataset");
    Some((coord, ds))
}

#[test]
fn serves_batched_requests_with_full_batches() {
    let Some((coord, ds)) = start(8, 50) else { return };
    let n = 32;
    let mut rxs = Vec::new();
    for i in 0..n {
        rxs.push(
            coord
                .submit(Target::ssa(4), ds.image(i % ds.len()).to_vec(), SeedPolicy::PerBatch)
                .expect("submit"),
        );
    }
    let mut batch_sizes = Vec::new();
    for rx in rxs {
        let resp = rx.recv().expect("response");
        assert!(resp.logits.len() == 10);
        batch_sizes.push(resp.batch_size);
    }
    // all submitted up front with generous delay: batches should fill
    assert!(
        batch_sizes.iter().filter(|&&b| b == 8).count() >= 24,
        "expected mostly full batches, got {batch_sizes:?}"
    );
    coord.shutdown();
}

#[test]
fn mixed_targets_route_correctly_and_match_direct_inference() {
    let Some((coord, ds)) = start(4, 5) else { return };
    // fixed seed + single-request batches => reproducible routing check
    let img = ds.image(3).to_vec();
    let targets =
        [Target::ann(), Target::ssa(4), Target::ssa(10), Target::spikformer(10)];
    for t in targets {
        let r = coord
            .classify(t.clone(), img.clone(), SeedPolicy::Fixed(42))
            .expect("classify");
        assert_eq!(r.logits.len(), 10, "target {t:?}");
    }
    coord.shutdown();
}

#[test]
fn fixed_seed_is_reproducible_across_requests() {
    let Some((coord, ds)) = start(1, 1) else { return };
    let img = ds.image(0).to_vec();
    let a = coord.classify(Target::ssa(4), img.clone(), SeedPolicy::Fixed(7)).unwrap();
    let b = coord.classify(Target::ssa(4), img, SeedPolicy::Fixed(7)).unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.seed, 7);
    coord.shutdown();
}

#[test]
fn ensemble_reduces_logit_variance() {
    let Some((coord, ds)) = start(1, 1) else { return };
    let img = ds.image(1).to_vec();
    let spread = |policy: SeedPolicy, reps: usize| -> f64 {
        let mut tops = Vec::new();
        for _ in 0..reps {
            let r = coord.classify(Target::ssa(4), img.clone(), policy).unwrap();
            tops.push(r.logits[r.class] as f64);
        }
        let mean = tops.iter().sum::<f64>() / tops.len() as f64;
        tops.iter().map(|v| (v - mean).powi(2)).sum::<f64>() / tops.len() as f64
    };
    let var_single = spread(SeedPolicy::PerBatch, 12);
    let var_ens = spread(SeedPolicy::Ensemble(8), 12);
    assert!(
        var_ens <= var_single + 1e-9,
        "ensemble should not increase variance: {var_ens} vs {var_single}"
    );
    coord.shutdown();
}

#[test]
fn submit_validates_inputs() {
    let Some((coord, _ds)) = start(2, 1) else { return };
    match coord.submit(Target::ssa(4), vec![0.0; 3], SeedPolicy::PerBatch) {
        Err(ServeError::BadImage { got: 3, .. }) => {}
        other => panic!("expected BadImage, got {other:?}"),
    }
    match coord.submit(Target::ssa(999), vec![0.0; 256], SeedPolicy::PerBatch) {
        Err(ServeError::UnknownTarget(_)) => {}
        other => panic!("expected UnknownTarget, got {other:?}"),
    }
    coord.shutdown();
}

#[test]
fn shutdown_rejects_new_requests() {
    let Some((coord, ds)) = start(2, 1) else { return };
    let img = ds.image(0).to_vec();
    // answer one request, then shut down
    coord.classify(Target::ssa(4), img, SeedPolicy::PerBatch).expect("classify");
    coord.shutdown();
    // a new coordinator can start again cleanly afterwards
    let Some((coord2, ds2)) = start(2, 1) else { return };
    coord2.classify(Target::ssa(4), ds2.image(0).to_vec(), SeedPolicy::PerBatch).unwrap();
    coord2.shutdown();
}

#[test]
fn concurrent_submitters_all_get_answers() {
    let Some((coord, ds)) = start(8, 3) else { return };
    let coord = std::sync::Arc::new(coord);
    let ds = std::sync::Arc::new(ds);
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let c = std::sync::Arc::clone(&coord);
        let d = std::sync::Arc::clone(&ds);
        handles.push(std::thread::spawn(move || {
            let mut ok = 0;
            for i in 0..16 {
                let idx = (t as usize * 16 + i) % d.len();
                let r = c
                    .classify(Target::ssa(4), d.image(idx).to_vec(), SeedPolicy::PerBatch)
                    .expect("classify");
                assert!(r.class < 10);
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 64);
}
