//! Forward-pass bit-exactness regression for the spike-native rewrite.
//!
//! The spike-domain GEMM + scratch-arena hot path must be *byte-identical*
//! to the pre-rewrite implementation for fixed seeds.  The pre-rewrite
//! path is retained verbatim as `NativeModel::infer_image_reference`
//! (dense `to_f01` + `Tensor::matmul`, allocating per step), so these
//! tests compare `f32::to_bits` of every logit the two paths produce —
//! across architectures, seeds, batch placements, and `infer_rows`'s
//! pinned-stream seam the worker pool depends on.

use ssa_repro::attention::model::{image_seed, Arch, ModelGeometry, NativeModel};
use ssa_repro::config::{LifConfig, PrngSharing};
use ssa_repro::runtime::weights::test_support::build_weights;
use ssa_repro::util::rng::Xoshiro256;

/// 8x8 images, 4x4 patches -> N=4, D=16, H=2, M=32, 2 layers, 3 classes.
fn geometry(sharing: PrngSharing) -> ModelGeometry {
    ModelGeometry {
        image_size: 8,
        patch_size: 4,
        n_tokens: 4,
        patch_dim: 16,
        d_model: 16,
        n_heads: 2,
        d_head: 8,
        d_mlp: 32,
        n_layers: 2,
        n_classes: 3,
        time_steps: 5,
        lif: LifConfig::default(),
        prng_sharing: sharing,
        spikformer_scale: 0.25,
    }
}

fn model(arch: Arch, sharing: PrngSharing) -> NativeModel {
    let geo = geometry(sharing);
    let w = build_weights(
        geo.patch_dim,
        geo.d_model,
        geo.n_tokens,
        geo.d_mlp,
        geo.n_layers,
        geo.n_classes,
        0xFACE,
    );
    NativeModel::from_weights(geo, arch, &w).expect("bind regression model")
}

fn images(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = Xoshiro256::new(seed);
    (0..n * 64).map(|_| rng.next_f32()).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: logit {i}: {x} vs {y}");
    }
}

#[test]
fn infer_rows_byte_identical_to_dense_reference() {
    for (arch, name) in [(Arch::Ssa, "ssa"), (Arch::Spikformer, "spikformer")] {
        for sharing in [PrngSharing::PerRow, PrngSharing::Independent, PrngSharing::Global]
        {
            let m = model(arch, sharing);
            let batch = 3;
            let imgs = images(batch, 0x1234);
            let row_seeds = [7u64, 7, 0xDEAD_BEEF];
            let fast = m.infer_rows(&imgs, batch, &row_seeds).unwrap();
            let mut dense = Vec::new();
            for i in 0..batch {
                dense.extend(
                    m.infer_image_reference(&imgs[i * 64..(i + 1) * 64], row_seeds[i])
                        .unwrap(),
                );
            }
            assert_bits_eq(&fast, &dense, &format!("{name}/{sharing:?}"));
        }
    }
}

#[test]
fn batched_infer_byte_identical_to_dense_reference() {
    let m = model(Arch::Ssa, PrngSharing::PerRow);
    let batch = 4;
    let imgs = images(batch, 0x9999);
    for seed in [0u32, 42, u32::MAX] {
        let fast = m.infer(&imgs, batch, seed).unwrap();
        let mut dense = Vec::new();
        for i in 0..batch {
            dense.extend(
                m.infer_image_reference(&imgs[i * 64..(i + 1) * 64], image_seed(seed, i))
                    .unwrap(),
            );
        }
        assert_bits_eq(&fast, &dense, &format!("seed {seed}"));
    }
}

#[test]
fn repeated_requests_on_one_model_stay_deterministic() {
    // Scratch arenas are rebuilt per request; back-to-back requests on the
    // same model must not leak state between inferences.
    let m = model(Arch::Ssa, PrngSharing::PerRow);
    let imgs = images(1, 5);
    let img = imgs.as_slice();
    let a = m.infer_image(img, 99).unwrap();
    let _ = m.infer_image(img, 100).unwrap(); // interleave a different stream
    let b = m.infer_image(img, 99).unwrap();
    assert_bits_eq(&a, &b, "replay after interleaved request");
}
