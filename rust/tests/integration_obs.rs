//! Observability integration: the tracing pipeline end to end against a
//! live pool.  Pins the three contracts `rust/src/obs` ships under:
//!
//! 1. Tracing never moves a logit bit — fixed-seed results are
//!    byte-identical with `--trace on` vs `--trace off`, for exact and
//!    early-exit requests alike.
//! 2. The Prometheus exposition is well-formed (every `# TYPE` family
//!    has samples, no duplicate families) and covers every target of a
//!    mixed load run.
//! 3. `trace-dump` produces valid Chrome trace-event JSON carrying
//!    queue-wait, batch, and per-stage model spans for served requests.

use std::path::PathBuf;
use std::time::Duration;

use ssa_repro::anytime::ExitPolicy;
use ssa_repro::config::BackendKind;
use ssa_repro::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, SeedPolicy, Target,
};
use ssa_repro::loadgen::{self, SyntheticSpec};
use ssa_repro::util::json::Json;

const IMAGE: usize = 16;
const PX: usize = IMAGE * IMAGE;

fn artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ssa-obs-it-{}-{tag}", std::process::id()));
    let spec = SyntheticSpec {
        d_model: 16,
        n_heads: 2,
        d_mlp: 32,
        n_layers: 1,
        dataset_n: 16,
        ..SyntheticSpec::default()
    };
    loadgen::write_artifacts(&dir, &spec).expect("synthesize artifacts");
    dir
}

fn start(dir: PathBuf, workers: usize, trace: bool) -> Coordinator {
    let mut cfg = CoordinatorConfig::new(dir)
        .with_backend(BackendKind::Native)
        .with_workers(workers)
        .with_trace(trace);
    cfg.policy = BatchPolicy { max_batch: 4, max_delay: Duration::from_millis(3) };
    cfg.preload = vec!["ssa_t4".into()];
    Coordinator::start(cfg).expect("coordinator must start")
}

fn image(i: usize) -> Vec<f32> {
    (0..PX).map(|p| ((i * 31 + p * 7) % 97) as f32 / 96.0).collect()
}

// --- contract 1: tracing is bit-exact ----------------------------------------

#[test]
fn fixed_seed_results_bit_identical_tracing_on_vs_off() {
    let dir = artifacts("bit-exact");
    // (class, logits, steps_used, confidence) per request, exact + margin
    let run = |trace: bool| -> Vec<(usize, Vec<f32>, usize, f32)> {
        let coord = start(dir.clone(), 2, trace);
        let mut out = Vec::new();
        for i in 0..12 {
            let exit = if i % 2 == 0 {
                ExitPolicy::Full
            } else {
                ExitPolicy::parse("margin:0.5:2").unwrap()
            };
            let r = coord
                .classify_anytime(Target::ssa(4), image(i), SeedPolicy::Fixed(77), exit)
                .expect("classify");
            out.push((r.class, r.logits, r.steps_used, r.confidence));
        }
        coord.shutdown();
        out
    };
    assert_eq!(
        run(true),
        run(false),
        "fixed-seed responses must be byte-identical with tracing on vs off"
    );
}

// --- contract 2: Prometheus exposition ---------------------------------------

#[test]
fn prometheus_exposition_is_well_formed_and_covers_mixed_run_targets() {
    let coord = start(artifacts("prom"), 2, true);
    let targets = [Target::ssa(4), Target::ann(), Target::spikformer(4)];
    for i in 0..18 {
        coord
            .classify(targets[i % targets.len()].clone(), image(i), SeedPolicy::PerBatch)
            .expect("classify");
    }
    let text = coord.metrics_prometheus();
    coord.shutdown();

    // every # TYPE family has at least one sample, and no family repeats
    let mut families: Vec<&str> = Vec::new();
    for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
        let name = line.split_whitespace().nth(2).expect("# TYPE NAME KIND");
        assert!(!families.contains(&name), "duplicate family {name}");
        families.push(name);
        let has_sample = text.lines().any(|l| {
            l.starts_with(&format!("{name} ")) || l.starts_with(&format!("{name}{{"))
        });
        assert!(has_sample, "family {name} declared but never sampled");
    }
    assert!(!families.is_empty(), "exposition must declare families");

    for key in ["ssa_t4", "ann", "spikformer_t4"] {
        assert!(
            text.contains(&format!("ssa_requests_total{{target=\"{key}\"}}")),
            "target {key} missing from exposition:\n{text}"
        );
    }
    assert!(text.contains("ssa_queue_depth "), "queue depth gauge present");
    assert!(text.contains("ssa_queue_oldest_age_us "), "oldest-age gauge present");
    assert!(text.contains("ssa_request_latency_us_bucket{"), "latency histogram present");
    assert!(text.contains("ssa_steps_used_bucket{"), "steps-used histogram present");
    assert!(text.contains("ssa_confidence_margin_mean{"), "margin gauge present");
    assert!(text.contains("ssa_worker_utilization_ratio{"), "worker gauges present");
    assert!(text.contains("ssa_trace_spans_written_total "), "span counters present");
}

// --- contract 3: Chrome trace dump -------------------------------------------

#[test]
fn trace_dump_is_valid_chrome_json_with_lifecycle_spans() {
    let coord = start(artifacts("chrome"), 2, true);
    let mut ids = Vec::new();
    for i in 0..12 {
        let r = coord
            .classify(Target::ssa(4), image(i), SeedPolicy::Fixed(9))
            .expect("classify");
        ids.push(r.id);
    }
    let dump = coord.trace_dump_json();
    coord.shutdown();

    let doc = Json::parse(&dump).expect("trace dump must be valid JSON");
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("Chrome trace JSON has a traceEvents array");
    let spans: Vec<&Json> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .collect();
    assert!(!spans.is_empty(), "served requests must leave spans");
    for e in &spans {
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0);
    }
    let named = |n: &str| -> usize {
        spans.iter().filter(|e| e.get("name").and_then(Json::as_str) == Some(n)).count()
    };
    // every request waited in the queue; batches carry forward + stages
    assert_eq!(named("queue_wait"), ids.len(), "one queue_wait span per request");
    assert!(named("batch") > 0, "batch spans recorded");
    assert!(named("model_forward") > 0, "model forward spans recorded");
    for stage in ["stage_embed", "stage_qkv", "stage_attn", "stage_mlp", "stage_readout"] {
        assert_eq!(
            named(stage),
            named("model_forward"),
            "each traced batch carries a {stage} attribution span"
        );
    }
    // queue_wait spans carry the request id of every request we sent
    let span_reqs: Vec<u64> = spans
        .iter()
        .filter(|e| e.get("name").and_then(Json::as_str) == Some("queue_wait"))
        .filter_map(|e| e.get("args").and_then(|a| a.get("req")).and_then(Json::as_f64))
        .map(|v| v as u64)
        .collect();
    for id in &ids {
        assert!(span_reqs.contains(id), "request {id} missing a queue_wait span");
    }
}

/// `--trace off` is a true zero-tracing baseline: nothing is recorded,
/// and the dump renders an empty (but still valid) trace document.
#[test]
fn trace_off_records_nothing() {
    let coord = start(artifacts("trace-off"), 1, false);
    for i in 0..6 {
        coord.classify(Target::ssa(4), image(i), SeedPolicy::PerBatch).expect("classify");
    }
    let dump = coord.trace_dump_json();
    coord.shutdown();
    let doc = Json::parse(&dump).expect("empty trace still parses");
    let spans = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .expect("traceEvents present")
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .count();
    assert_eq!(spans, 0, "--trace off must not record spans");
}
