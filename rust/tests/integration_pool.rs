//! Worker-pool integration: a multi-worker coordinator serves correct
//! results under concurrent load, drains gracefully on shutdown, and —
//! the load-bearing contract — produces bit-identical fixed-seed results
//! for any worker count.  Also smoke-tests the load-generation subsystem
//! end-to-end against a live pool (closed and open loop), including the
//! BENCH_serving.json report shape.
//!
//! Artifacts are synthesized by `loadgen::synthetic` — manifest + random
//! weights + dataset, no Python, no XLA.

use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

use ssa_repro::config::BackendKind;
use ssa_repro::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, SeedPolicy, Target,
};
use ssa_repro::loadgen::{
    self, ArrivalMode, BenchReport, BenchRun, ImageSource, LoadOpts, LoadSpec, Scenario,
    SyntheticSpec,
};
use ssa_repro::util::json::Json;

const IMAGE: usize = 16;
const PX: usize = IMAGE * IMAGE;

/// Small-but-real geometry: 16x16 images, 1 encoder layer, T=4.
fn artifacts(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("ssa-pool-it-{}-{tag}", std::process::id()));
    let spec = SyntheticSpec {
        d_model: 16,
        n_heads: 2,
        d_mlp: 32,
        n_layers: 1,
        dataset_n: 16,
        ..SyntheticSpec::default()
    };
    loadgen::write_artifacts(&dir, &spec).expect("synthesize artifacts");
    dir
}

fn start(dir: PathBuf, workers: usize, max_batch: usize, delay_ms: u64) -> Coordinator {
    start_intra(dir, workers, 1, max_batch, delay_ms)
}

fn start_intra(
    dir: PathBuf,
    workers: usize,
    intra_threads: usize,
    max_batch: usize,
    delay_ms: u64,
) -> Coordinator {
    let mut cfg = CoordinatorConfig::new(dir)
        .with_backend(BackendKind::Native)
        .with_workers(workers)
        .with_intra_threads(intra_threads);
    cfg.policy = BatchPolicy { max_batch, max_delay: Duration::from_millis(delay_ms) };
    cfg.preload = vec!["ssa_t4".into()];
    Coordinator::start(cfg).expect("pool coordinator must start")
}

fn image(i: usize) -> Vec<f32> {
    (0..PX).map(|p| ((i * 31 + p * 7) % 97) as f32 / 96.0).collect()
}

// --- fixed-seed determinism across worker counts (satellite) ----------------

#[test]
fn fixed_seed_results_bit_identical_across_worker_counts() {
    let dir = artifacts("determinism");
    // Returns (logits, resident weight bytes): the shared-store contract
    // is that the first is identical and the second is flat across N.
    let run = |workers: usize| -> (Vec<Vec<f32>>, u64) {
        let coord = start(dir.clone(), workers, 4, 5);
        assert_eq!(coord.workers(), workers);
        // submit everything up front so batch composition genuinely races
        // across workers in the multi-worker run
        let rxs: Vec<_> = (0..24)
            .map(|i| {
                coord
                    .submit(Target::ssa(4), image(i), SeedPolicy::Fixed(77))
                    .expect("submit")
            })
            .collect();
        let out = rxs
            .into_iter()
            .map(|rx| {
                let r = rx.recv().expect("reply");
                assert_eq!(r.generation, 1, "fresh store serves generation 1");
                r.logits
            })
            .collect();
        let resident = coord.weight_store_snapshot().resident_bytes;
        coord.shutdown();
        (out, resident)
    };
    let (single, bytes_1) = run(1);
    let (dual, bytes_2) = run(2);
    let (pooled, bytes_4) = run(4);
    assert_eq!(
        single, dual,
        "Fixed(77) logits must be bit-identical for --workers 1 vs --workers 2"
    );
    assert_eq!(
        single, pooled,
        "Fixed(77) logits must be bit-identical for --workers 1 vs --workers 4"
    );
    // One shared copy per variant: growing the pool must not grow the
    // resident weight footprint by a single byte.
    assert!(bytes_1 > 0, "loaded variant must report nonzero weight bytes");
    assert_eq!(bytes_1, bytes_2, "resident weight bytes independent of worker count");
    assert_eq!(bytes_1, bytes_4, "resident weight bytes independent of worker count");
}

#[test]
fn fixed_seed_results_bit_identical_across_intra_thread_counts() {
    // The intra-request twin of the worker-count determinism contract:
    // splitting each request across batch rows and attention heads inside
    // a worker must not move a single logit bit, for any combination of
    // worker count and intra-thread budget.  (The pool may clamp the
    // requested budget on small machines — the contract holds for the
    // clamped value too, which is exactly what runs here.)
    let dir = artifacts("intra-determinism");
    let run = |workers: usize, intra: usize| -> Vec<Vec<f32>> {
        let coord = start_intra(dir.clone(), workers, intra, 4, 5);
        let rxs: Vec<_> = (0..24)
            .map(|i| {
                coord
                    .submit(Target::ssa(4), image(i), SeedPolicy::Fixed(77))
                    .expect("submit")
            })
            .collect();
        let out = rxs.into_iter().map(|rx| rx.recv().expect("reply").logits).collect();
        coord.shutdown();
        out
    };
    let sequential = run(1, 1);
    assert_eq!(
        sequential,
        run(1, 4),
        "Fixed(77) logits must be bit-identical for --intra-threads 1 vs 4"
    );
    assert_eq!(
        sequential,
        run(2, 2),
        "Fixed(77) logits must be bit-identical for 2 workers x 2 intra-threads"
    );
}

// --- correctness under concurrent multi-target load --------------------------

#[test]
fn multi_worker_pool_serves_concurrent_mixed_load() {
    let coord = Arc::new(start(artifacts("mixed-load"), 4, 4, 3));
    let mut handles = Vec::new();
    for t in 0..4usize {
        let c = Arc::clone(&coord);
        handles.push(std::thread::spawn(move || {
            let targets =
                [Target::ssa(4), Target::ann(), Target::spikformer(4), Target::ssa(4)];
            let mut ok = 0;
            for i in 0..16 {
                let r = c
                    .classify(
                        targets[(t + i) % targets.len()].clone(),
                        image(t * 16 + i),
                        SeedPolicy::PerBatch,
                    )
                    .expect("classify");
                assert_eq!(r.logits.len(), 10);
                assert!(r.class < 10);
                assert!(r.logits.iter().all(|v| v.is_finite()));
                ok += 1;
            }
            ok
        }));
    }
    let total: usize = handles.into_iter().map(|h| h.join().unwrap()).sum();
    assert_eq!(total, 64);

    // the pool accounted every batch to some worker, and all 4 registered
    let workers = coord.metrics().worker_report();
    assert_eq!(workers.len(), 4, "all pool workers register in metrics");
    let worker_reqs: u64 = workers.iter().map(|w| w.requests).sum();
    assert_eq!(worker_reqs, 64, "every request accounted to exactly one worker");
    let coord = Arc::try_unwrap(coord).unwrap_or_else(|_| panic!("coordinator still shared"));
    coord.shutdown();
}

#[test]
fn shutdown_drains_queued_requests() {
    let coord = start(artifacts("drain"), 4, 4, 2);
    let rxs: Vec<_> = (0..40)
        .map(|i| {
            coord
                .submit(Target::ssa(4), image(i), SeedPolicy::PerBatch)
                .expect("submit")
        })
        .collect();
    coord.shutdown(); // close + join: must drain, not drop
    for (i, rx) in rxs.into_iter().enumerate() {
        rx.recv().unwrap_or_else(|_| panic!("request {i} dropped during graceful shutdown"));
    }
}

// --- load generation end-to-end ----------------------------------------------

#[test]
fn closed_loop_loadgen_drives_live_pool() {
    let dir = artifacts("loadgen-closed");
    let coord = start(dir, 2, 4, 2);
    let scenario =
        Scenario::parse("ssa_t4*2,ann", SeedPolicy::PerBatch).expect("scenario");
    let spec = LoadSpec {
        mode: ArrivalMode::Closed { concurrency: 4 },
        duration: Duration::from_millis(300),
        scenario,
        seed: 42,
        opts: LoadOpts::default(),
    };
    let images = ImageSource::synthetic(IMAGE, 16, 7);
    let stats = loadgen::run(&coord, &spec, &images).expect("loadgen run");
    assert!(stats.ok > 0, "closed loop must complete requests");
    assert_eq!(stats.errors, 0, "no errors expected on a healthy pool");
    assert_eq!(stats.ok, stats.latency.count(), "every ok reply has a latency sample");
    assert!(stats.throughput_rps() > 0.0);

    let report = BenchReport {
        scenario: spec.scenario.name.clone(),
        mode: spec.mode.describe(),
        backend: "native".into(),
        transport: "in-process".into(),
        duration_s: 0.3,
        runs: vec![BenchRun::new(
            coord.workers(),
            stats,
            coord.metrics().report(),
            coord.metrics().worker_report(),
        )],
    };
    let parsed = Json::parse(&report.to_json().to_string()).expect("report JSON parses");
    assert_eq!(parsed.str_field("bench").unwrap(), "serving");
    let runs = parsed.get("runs").and_then(Json::as_arr).unwrap();
    assert_eq!(runs[0].usize_field("workers").unwrap(), 2);
    assert!(
        !runs[0].get("worker_util").and_then(Json::as_arr).unwrap().is_empty(),
        "per-worker utilization recorded"
    );
    coord.shutdown();
}

#[test]
fn open_loop_loadgen_sustains_poisson_arrivals() {
    let dir = artifacts("loadgen-open");
    let coord = start(dir, 2, 4, 2);
    let spec = LoadSpec {
        mode: ArrivalMode::Open { rps: 150.0 },
        duration: Duration::from_millis(300),
        scenario: Scenario::uniform(Target::ssa(4), SeedPolicy::PerBatch),
        seed: 9,
        opts: LoadOpts::default(),
    };
    let images = ImageSource::synthetic(IMAGE, 16, 8);
    let stats = loadgen::run(&coord, &spec, &images).expect("loadgen run");
    assert!(stats.offered > 0, "pacer must submit");
    assert_eq!(stats.ok + stats.errors, stats.offered, "every submit resolves");
    assert_eq!(stats.errors, 0);
    coord.shutdown();
}
