//! Offline API-compatible subset of `anyhow` (the image has no registry
//! access, and this crate's needs are small): [`Error`], [`Result`],
//! [`Context`], and the `anyhow!` / `bail!` / `ensure!` macros.
//!
//! Semantics mirrored from upstream:
//! * `{}` displays the outermost message/context only;
//! * `{:#}` (and `Debug`) display the whole chain, `": "`-joined;
//! * `From<E: std::error::Error + Send + Sync + 'static>` captures the
//!   `source()` chain, so `?` works on any std error;
//! * `.context(..)` / `.with_context(..)` work on `Result<T, E>` for std
//!   errors *and* for `Error` itself (via the same private-trait trick
//!   upstream uses), and on `Option<T>`.
//!
//! Not implemented (unused in this repo): downcasting, backtraces,
//! `Error::new`, `Chain` iteration.

use std::fmt;

/// `Result<T, anyhow::Error>` with the upstream default-parameter shape.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error chain: `chain[0]` is the outermost context message.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a displayable message (upstream `Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (upstream `Error::context`).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    fn from_std<E: std::error::Error + ?Sized>(error: &E) -> Self {
        let mut chain = vec![error.to_string()];
        let mut source = error.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(&self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.chain.join(": "))
    }
}

// Coherence note: this blanket impl is legal alongside the lack of a
// `std::error::Error` impl for `Error` — the overlap with `From<T> for T`
// is ruled out within this crate (same reasoning as upstream anyhow).
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::from_std(&error)
    }
}

mod ext {
    /// Private unifier so `Context` has one blanket impl covering both
    /// std errors and `Error` itself (upstream's `ext::StdError` trick).
    pub trait IntoError {
        fn into_error(self) -> super::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> super::Error {
            super::Error::from_std(&self)
        }
    }

    impl IntoError for super::Error {
        fn into_error(self) -> super::Error {
            self
        }
    }
}

/// Attach context to errors (`Result`) or turn `None` into an error.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::Error::msg(concat!(
                "Condition failed: `",
                stringify!($cond),
                "`"
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "root cause")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = std::result::Result::<(), _>::Err(io_err())
            .context("outer")
            .unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: root cause");
        assert_eq!(format!("{e:?}"), "outer: root cause");
    }

    #[test]
    fn context_on_anyhow_error_and_option() {
        let e: Error = std::result::Result::<(), Error>::Err(Error::msg("inner"))
            .with_context(|| format!("outer {}", 1))
            .unwrap_err();
        assert_eq!(format!("{e:#}"), "outer 1: inner");
        let o: Result<u8> = None.context("missing");
        assert_eq!(format!("{}", o.unwrap_err()), "missing");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10);
            ensure!(x != 3, "three is right out (got {x})");
            if x == 4 {
                bail!("no {}", x);
            }
            Ok(x)
        }
        assert_eq!(f(1).unwrap(), 1);
        assert!(format!("{}", f(12).unwrap_err()).contains("Condition failed"));
        assert!(format!("{}", f(3).unwrap_err()).contains("three"));
        assert!(format!("{}", f(4).unwrap_err()).contains("no 4"));
        let e = anyhow!("value {v:?}", v = Some(1));
        assert!(format!("{e}").contains("Some(1)"));
    }

    #[test]
    fn question_mark_conversion() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }
}
