//! Bench: regenerate Table II (E2) and sweep the energy model across the
//! token-count range the paper targets (N = 16..128), reporting how the
//! SSA advantage scales.

use ssa_repro::bench::BenchSet;
use ssa_repro::config::AttnConfig;
use ssa_repro::energy::{ActivityFactors, TableTwo, TechEnergies};

fn main() {
    let mut set = BenchSet::new("table2_energy (E2)");
    set.start();

    // the paper row
    println!("{}", ssa_repro::experiments::table2::run());

    // N sweep: the edge-Transformer range called out in §III-C
    println!("N sweep (D=384, H=8, D_K=48, T=10):");
    println!("|  N  | ANN total (uJ) | SSA total (uJ) | gain |");
    for n in [16usize, 32, 64, 128] {
        let cfg = AttnConfig {
            n_tokens: n,
            d_model: 384,
            n_heads: 8,
            d_head: 48,
            time_steps: 10,
        };
        let t2 =
            TableTwo::compute(&cfg, &ActivityFactors::default(), &TechEnergies::cmos_45nm());
        println!(
            "| {n:>3} | {:>14.2} | {:>14.2} | {:>3.1}x |",
            t2.ann.total_uj(),
            t2.ssa.total_uj(),
            t2.ann.total_uj() / t2.ssa.total_uj()
        );
    }

    // model-evaluation cost itself (it's on experiment hot paths)
    let cfg = AttnConfig::vit_small_paper();
    set.bench("TableTwo::compute (paper geometry)", || {
        std::hint::black_box(TableTwo::compute(
            &cfg,
            &ActivityFactors::default(),
            &TechEnergies::cmos_45nm(),
        ));
    });
    set.finish();
}
