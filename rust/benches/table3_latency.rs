//! Bench: regenerate Table III (E3) — the analytic device rows plus real
//! wall-clock measurements of the Rust golden models on this host (the
//! ground truth for the CPU column of the device model).

use ssa_repro::bench::BenchSet;
use ssa_repro::config::{AttnConfig, LifConfig, PrngSharing};
use ssa_repro::attention::spikformer::SpikformerAttention;
use ssa_repro::attention::ssa::SsaAttention;
use ssa_repro::attention::softmax_attention;
use ssa_repro::hw::SpikeStreams;
use ssa_repro::tensor::Tensor;
use ssa_repro::util::rng::Xoshiro256;

fn main() {
    println!("{}", ssa_repro::experiments::table3::run(false).expect("table3"));

    let cfg = AttnConfig::vit_small_paper();
    let mut set = BenchSet::new("table3_latency — measured on this host (E3 ground truth)");
    set.start();

    // ANN attention block (all 8 heads, softmax fp32)
    let mut rng = Xoshiro256::new(1);
    let mk = |rng: &mut Xoshiro256| {
        let n = cfg.n_tokens * cfg.d_head;
        Tensor::from_vec(
            &[cfg.n_tokens, cfg.d_head],
            (0..n).map(|_| rng.next_normal() as f32).collect(),
        )
    };
    let heads: Vec<(Tensor, Tensor, Tensor)> =
        (0..cfg.n_heads).map(|_| (mk(&mut rng), mk(&mut rng), mk(&mut rng))).collect();
    set.bench_units("ANN attention block (8 heads, fp32)", Some(1.0), || {
        for (q, k, v) in &heads {
            std::hint::black_box(softmax_attention(q, k, v));
        }
    });

    // SSA software block (packed bits, T=10, 8 heads)
    let streams: Vec<SpikeStreams> = (0..cfg.n_heads)
        .map(|h| SpikeStreams::from_rates(&cfg, (0.5, 0.5, 0.5), 100 + h as u64))
        .collect();
    let mut ssa_heads: Vec<SsaAttention> = (0..cfg.n_heads)
        .map(|h| SsaAttention::new(cfg, PrngSharing::PerRow, 200 + h as u64))
        .collect();
    set.bench_units("SSA software block (8 heads, T=10, packed)", Some(1.0), || {
        for (h, ssa) in ssa_heads.iter_mut().enumerate() {
            let s = &streams[h];
            for t in 0..cfg.time_steps {
                std::hint::black_box(ssa.step(&s.q[t], &s.k[t], &s.v[t]));
            }
        }
    });

    // Spikformer software block
    let mut sf_heads: Vec<SpikformerAttention> = (0..cfg.n_heads)
        .map(|_| SpikformerAttention::new(cfg, 0.25, LifConfig::default()))
        .collect();
    set.bench_units("Spikformer software block (8 heads, T=10)", Some(1.0), || {
        for (h, sf) in sf_heads.iter_mut().enumerate() {
            let s = &streams[h];
            for t in 0..cfg.time_steps {
                std::hint::black_box(sf.step(&s.q[t], &s.k[t], &s.v[t]));
            }
        }
    });

    set.finish();
    println!(
        "\nNote: the paper's CPU (i7-12850HX) vs this container differ; the device model \
         reproduces the paper's ratios, the numbers above are this host's ground truth."
    );
}
