//! End-to-end native forward-pass benchmark (`cargo bench --bench
//! forward_native`): single-row + full-batch latency for SSA, Spikformer,
//! and ANN, the retained dense reference baseline, and per-stage
//! attribution.  Thin wrapper over [`ssa_repro::bench_native`] — the
//! `bench-native` CLI subcommand runs the same matrix and additionally
//! writes `BENCH_native.json`.
//!
//! Env knobs (benches take no CLI args under `cargo bench`):
//!   BENCH_BUDGET_S      wall budget per benchmark in seconds (default 1)
//!   BENCH_NATIVE_OUT    also write BENCH_native.json to this path

use std::path::Path;
use std::time::Duration;

use ssa_repro::bench_native::{run, BenchNativeOpts};

fn main() {
    let mut opts = BenchNativeOpts::default();
    if let Some(b) = std::env::var("BENCH_BUDGET_S").ok().and_then(|v| v.parse().ok()) {
        opts.budget = Duration::from_secs_f64(b);
    }
    let report = run(&opts).expect("bench-native run");
    print!("{}", report.render());
    if let Ok(out) = std::env::var("BENCH_NATIVE_OUT") {
        report.write(Path::new(&out)).expect("write BENCH_native.json");
        println!("wrote {out}");
    }
}
