//! Bench: E1 (Table I) — print the trained accuracy sweep and measure the
//! Rust-side inference throughput that the serving stack delivers per
//! variant, through the default inference backend (PJRT on `xla` builds,
//! the native forward pass otherwise).  Skips gracefully when artifacts
//! are missing (e.g. a bench run before `make artifacts`).

use std::path::Path;

use ssa_repro::bench::BenchSet;
use ssa_repro::config::BackendKind;
use ssa_repro::experiments::table1;
use ssa_repro::runtime::{create_backend, Dataset, Manifest};

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("table1_accuracy: artifacts/ missing — run `make artifacts` first (skipping)");
        return;
    }

    let backend = BackendKind::default();
    match table1::run(dir, None, backend) {
        Ok(s) => println!("{s}"),
        Err(e) => {
            println!("table1_accuracy: cannot load accuracy table: {e:#} (skipping)");
            return;
        }
    }

    let manifest = Manifest::load(dir).expect("manifest");
    let ds = Dataset::load(&manifest.dataset_test).expect("dataset");
    let engine = create_backend(backend).expect("backend");

    let mut set = BenchSet::new(&format!(
        "table1_accuracy — {} inference throughput",
        backend.name()
    ));
    set.start();
    for name in ["ann", "spikformer_t10", "ssa_t4", "ssa_t10", "ssa_t10_b1"] {
        let Ok(variant) = manifest.variant(name) else { continue };
        let model = engine.load(&manifest, variant).expect("load variant");
        let images = ds.batch(0, variant.batch).to_vec();
        let mut seed = 0u32;
        set.bench_units(
            &format!("infer {name} (batch={})", variant.batch),
            Some(variant.batch as f64),
            || {
                seed = seed.wrapping_add(1);
                std::hint::black_box(model.infer(&images, seed).expect("infer"));
            },
        );
    }
    set.finish();
}
