//! Ablation A3: time-step sweep — the accuracy/energy/latency tension of
//! Table I vs Table II as T grows (SC estimator error falls like 1/sqrt(T)
//! while energy and latency grow linearly).

use ssa_repro::config::{AttnConfig, PrngSharing};
use ssa_repro::energy::{ActivityFactors, TableTwo, TechEnergies};
use ssa_repro::hw::{simulate, SpikeStreams};

fn main() {
    println!("A3 — time-step sweep (demo geometry N=16, D_K=16)");
    println!("|  T  | est. MAE | SSA energy (uJ, paper dims) | FPGA latency (us) |");
    let tech = TechEnergies::cmos_45nm();
    let act = ActivityFactors::default();
    for t in [1usize, 2, 4, 8, 10, 16, 32] {
        let demo = AttnConfig::vit_tiny().with_time_steps(t);
        let mut mae = 0.0;
        let reps = 4;
        for seed in 0..reps {
            let streams = SpikeStreams::from_rates(&demo, (0.5, 0.4, 0.6), 70 + seed);
            let rep = simulate(demo, PrngSharing::PerRow, &streams, 80 + seed, 200.0, false);
            mae += rep.estimator_mae / reps as f64;
        }
        let paper = AttnConfig::vit_small_paper().with_time_steps(t);
        let e = TableTwo::compute(&paper, &act, &tech).ssa;
        let streams = SpikeStreams::from_rates(&paper, (0.5, 0.5, 0.5), 1);
        let rep = simulate(paper, PrngSharing::PerRow, &streams, 2, 200.0, false);
        println!(
            "| {t:>3} | {mae:>8.4} | {:>27.2} | {:>17.3} |",
            e.total_uj(),
            rep.fpga.latency_us
        );
    }
    println!(
        "\nshape: estimator error shrinks with T (Table I accuracy rises) while \
         energy/latency grow ~linearly (Table II/III) — the T=10 operating \
         point the paper picks balances the two."
    );
}
