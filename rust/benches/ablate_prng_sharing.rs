//! Ablation A1: PRNG reuse strategy [29] — estimator quality vs hardware
//! cost across Independent / PerRow / Global LFSR sharing.

use ssa_repro::bench::BenchSet;
use ssa_repro::config::{AttnConfig, PrngSharing};
use ssa_repro::hw::fpga;
use ssa_repro::hw::{simulate, SpikeStreams};

fn main() {
    let cfg = AttnConfig::vit_tiny().with_time_steps(10);
    println!("A1 — PRNG sharing ablation (N={}, D_K={}, T=10)", cfg.n_tokens, cfg.d_head);
    println!("| sharing     | LFSRs | est. MAE | LUTs  | power (W) | bit-exact |");

    let mut set = BenchSet::new("ablate_prng_sharing (A1)");
    for sharing in [PrngSharing::Independent, PrngSharing::PerRow, PrngSharing::Global] {
        // average estimator quality over several workloads
        let mut mae = 0.0;
        let reps = 5;
        let mut exact = true;
        let mut power = 0.0;
        for seed in 0..reps {
            let streams = SpikeStreams::from_rates(&cfg, (0.5, 0.4, 0.6), 50 + seed);
            let rep = simulate(cfg, sharing, &streams, 60 + seed, 200.0, false);
            mae += rep.estimator_mae / reps as f64;
            exact &= rep.matches_software;
            power = rep.fpga.total_w;
        }
        let (luts, _) = fpga::resources(&cfg, sharing);
        let lfsrs = match sharing {
            PrngSharing::Independent => cfg.n_tokens * cfg.n_tokens + cfg.n_tokens,
            PrngSharing::PerRow => cfg.n_tokens,
            PrngSharing::Global => 1,
        };
        println!(
            "| {sharing:<11?} | {lfsrs:>5} | {mae:>8.4} | {luts:>5} | {power:>9.2} | {exact:<9} |"
        );

        // simulator cost per sharing mode (sanity: sharing shouldn't slow it)
        let streams = SpikeStreams::from_rates(&cfg, (0.5, 0.5, 0.5), 99);
        set.bench(&format!("simulate {sharing:?}"), || {
            std::hint::black_box(simulate(cfg, sharing, &streams, 7, 200.0, false));
        });
    }
    println!(
        "\nshape: marginal rates stay unbiased under sharing (see \
         attention::ssa tests); correlation grows Independent -> Global while \
         area and power shrink — the paper adopts the per-row-style reuse [29]."
    );
    set.finish();
}
