//! Ablation A2: §III-D power-of-two simplification — comparator-only
//! Bernoulli encoders (pow2 N, D_K) vs the fixed-point divider path,
//! measuring sampling-probability quantization error and the energy delta.

use ssa_repro::attention::ssa::bern_compare;
use ssa_repro::bench::BenchSet;
use ssa_repro::config::AttnConfig;
use ssa_repro::energy::{ActivityFactors, TableTwo, TechEnergies};
use ssa_repro::hw::bernoulli_encoder::{BernoulliEncoder, EncoderPath};

fn main() {
    println!("A2 — pow2 comparator vs fixed-point divider encoders");

    // exactness: worst-case probability quantization error per modulus
    println!("| modulus m | path       | max |P(spike) - count/m| |");
    for m in [16u32, 48, 64, 100, 256] {
        let enc = BernoulliEncoder::new(m);
        let mut worst = 0.0f64;
        for count in 0..=m {
            let hits = (0..=u16::MAX).filter(|&u| bern_compare(u, count, m)).count();
            let p = hits as f64 / 65536.0;
            worst = worst.max((p - count as f64 / m as f64).abs());
        }
        println!(
            "| {m:>9} | {:<10} | {worst:>24.6} |",
            match enc.path() {
                EncoderPath::Pow2Compare => "pow2",
                EncoderPath::FixedPointDivider => "divider",
            }
        );
    }

    // energy: paper geometry (D_K=48, divider) vs pow2 variant (D_K=64)
    let tech = TechEnergies::cmos_45nm();
    let act = ActivityFactors::default();
    let paper = AttnConfig::vit_small_paper(); // D_K=48 -> divider on S encoders
    let pow2 = AttnConfig { d_head: 64, d_model: 512, ..paper }; // comparator-only
    let e_paper = TableTwo::compute(&paper, &act, &tech).ssa;
    let e_pow2 = TableTwo::compute(&pow2, &act, &tech).ssa;
    println!(
        "\nSSA processing energy: D_K=48 (divider) {:.3} uJ vs D_K=64 (pow2, larger dims!) {:.3} uJ",
        e_paper.processing_uj, e_pow2.processing_uj
    );
    println!("(pow2 removes the per-sample normalizer; §III-D)");

    // microbench the two comparator datapaths
    let mut set = BenchSet::new("ablate_pow2 comparator datapaths");
    set.start();
    let enc64 = BernoulliEncoder::new(64);
    let mut acc = false;
    set.bench("pow2 bit-slice comparator (m=64)", || {
        for w in 0..4096u16 {
            acc ^= enc64.sample_pow2_datapath(w, (w % 65) as u32);
        }
        std::hint::black_box(acc);
    });
    let enc48 = BernoulliEncoder::new(48);
    set.bench("fixed-point divider comparator (m=48)", || {
        for w in 0..4096u16 {
            acc ^= enc48.sample(w, (w % 49) as u32);
        }
        std::hint::black_box(acc);
    });
    set.finish();
}
