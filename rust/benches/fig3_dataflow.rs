//! Bench: E6 (Fig. 3) — cycle-accurate simulator throughput across array
//! sizes, plus the rendered dataflow schedule for the demo geometry.

use ssa_repro::bench::BenchSet;
use ssa_repro::config::{AttnConfig, PrngSharing};
use ssa_repro::experiments::figures;
use ssa_repro::hw::{SauArray, SpikeStreams};

fn main() {
    println!("{}", figures::fig3_dataflow(AttnConfig::vit_tiny().with_time_steps(3)));

    let mut set = BenchSet::new("fig3_dataflow — simulator throughput");
    set.start();
    for (n, d_k) in [(16usize, 16usize), (32, 32), (64, 48)] {
        let cfg = AttnConfig {
            n_tokens: n,
            d_model: d_k,
            n_heads: 1,
            d_head: d_k,
            time_steps: 10,
        };
        let streams = SpikeStreams::from_rates(&cfg, (0.5, 0.5, 0.5), 1);
        let cycles = ((cfg.time_steps + 1) * cfg.d_head) as f64;
        let mut arr = SauArray::new(cfg, PrngSharing::PerRow, 2);
        set.bench_units(
            &format!("simulate N={n} D_K={d_k} T=10 (cycles/s)"),
            Some(cycles),
            || {
                arr.reset_datapath();
                std::hint::black_box(arr.run(&streams.q, &streams.k, &streams.v, None));
            },
        );
    }
    set.finish();
}
