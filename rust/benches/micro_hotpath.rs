//! Microbenchmarks of the L3 hot paths (§Perf): packed AND+popcount,
//! one SSA software step, LFSR word generation, Bernoulli comparator,
//! f32 matmul, and a full cycle-accurate array run.

use ssa_repro::attention::ssa::{bern_compare, SsaAttention};
use ssa_repro::bench::BenchSet;
use ssa_repro::config::{AttnConfig, PrngSharing};
use ssa_repro::hw::{SauArray, SpikeStreams};
use ssa_repro::tensor::Tensor;
use ssa_repro::util::bitpack::BitMatrix;
use ssa_repro::util::rng::{Lfsr16, Xoshiro256};
use ssa_repro::util::simd;

fn main() {
    let mut set = BenchSet::new("micro_hotpath");
    set.start();

    // packed AND+popcount — the CPU analogue of the SAU AND gates
    let mut rng = Xoshiro256::new(1);
    let vals = |rng: &mut Xoshiro256, n: usize| -> Vec<f32> {
        (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect()
    };
    let a = BitMatrix::from_f01(64, 384, &vals(&mut rng, 64 * 384));
    let b = BitMatrix::from_f01(64, 384, &vals(&mut rng, 64 * 384));
    set.bench_units("and_popcount 64x64 pairs (D=384)", Some((64 * 64) as f64), || {
        let mut acc = 0u32;
        for i in 0..64 {
            for j in 0..64 {
                acc = acc.wrapping_add(a.and_popcount(i, &b, j));
            }
        }
        std::hint::black_box(acc);
    });

    // the raw kernels, scalar vs dispatched, at several word widths — the
    // dispatcher falls back to scalar below the wide kernels' minimum
    // length, so short rows should show ~1x and long rows the SIMD win
    println!(
        "popcount kernel: {} (cpu features: {})",
        simd::kernel_name(),
        simd::cpu_features()
    );
    for words in [2usize, 6, 16, 64, 256] {
        let x: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let y: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
        let bits = Some((words * 64) as f64);
        set.bench_units(&format!("and_popcount scalar ({words}w)"), bits, || {
            std::hint::black_box(simd::and_popcount_scalar(
                std::hint::black_box(&x),
                std::hint::black_box(&y),
            ));
        });
        set.bench_units(&format!("and_popcount dispatched ({words}w)"), bits, || {
            std::hint::black_box(simd::and_popcount(
                std::hint::black_box(&x),
                std::hint::black_box(&y),
            ));
        });
    }

    // the 64x64 bit-transpose block behind BitMatrix::transpose_into
    let mut block = [0u64; 64];
    for w in block.iter_mut() {
        *w = rng.next_u64();
    }
    set.bench_units("transpose_64x64 block", Some(64.0 * 64.0), || {
        simd::transpose_64x64(std::hint::black_box(&mut block));
        std::hint::black_box(&block);
    });

    // one software SSA step at paper head geometry
    let cfg = AttnConfig::vit_small_paper();
    let streams = SpikeStreams::from_rates(&cfg, (0.5, 0.5, 0.5), 2);
    let mut ssa = SsaAttention::new(cfg, PrngSharing::PerRow, 3);
    set.bench("SsaAttention::step (N=64, D_K=48)", || {
        std::hint::black_box(ssa.step(&streams.q[0], &streams.k[0], &streams.v[0]));
    });

    // LFSR word generation
    let mut lfsr = Lfsr16::new(0xACE1);
    set.bench_units("Lfsr16::next_u16 x 4096", Some(4096.0), || {
        let mut acc = 0u16;
        for _ in 0..4096 {
            acc ^= lfsr.next_u16();
        }
        std::hint::black_box(acc);
    });

    // Bernoulli comparator
    set.bench_units("bern_compare x 4096 (m=48)", Some(4096.0), || {
        let mut acc = false;
        for w in 0..4096u16 {
            acc ^= bern_compare(w, (w % 49) as u32, 48);
        }
        std::hint::black_box(acc);
    });

    // f32 matmul golden path
    let m1 = Tensor::from_vec(&[64, 384], vals(&mut rng, 64 * 384));
    let m2 = Tensor::from_vec(&[384, 64], vals(&mut rng, 384 * 64));
    set.bench("Tensor::matmul 64x384x64", || {
        std::hint::black_box(m1.matmul(&m2));
    });

    // full cycle-accurate run, demo geometry
    let demo = AttnConfig::vit_tiny().with_time_steps(10);
    let dstreams = SpikeStreams::from_rates(&demo, (0.5, 0.5, 0.5), 4);
    let mut arr = SauArray::new(demo, PrngSharing::PerRow, 5);
    set.bench("SauArray::run (N=16, D_K=16, T=10)", || {
        arr.reset_datapath();
        std::hint::black_box(arr.run(&dstreams.q, &dstreams.k, &dstreams.v, None));
    });

    set.finish();
}
