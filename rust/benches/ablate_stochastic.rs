//! Ablation A4: stochastic Bernoulli stages vs their deterministic
//! expectation — isolates what SC noise costs in output fidelity and what
//! the stochastic datapath saves in hardware.

use ssa_repro::attention::ssa::{ssa_expectation, SsaAttention};
use ssa_repro::bench::BenchSet;
use ssa_repro::config::{AttnConfig, PrngSharing};
use ssa_repro::hw::SpikeStreams;

fn main() {
    let cfg = AttnConfig::vit_tiny();
    println!("A4 — stochastic vs expectation attention (N=16, D_K=16)");
    println!("| averaging window T | mean abs deviation from expectation |");
    for t in [1usize, 4, 10, 40, 160] {
        let c = cfg.with_time_steps(t);
        let streams = SpikeStreams::from_rates(&c, (0.5, 0.4, 0.6), 11);
        let mut ssa = SsaAttention::new(c, PrngSharing::Independent, 13);
        let n = c.n_tokens;
        let d_k = c.d_head;
        let mut mean = vec![0.0f64; n * d_k];
        let mut expect = vec![0.0f64; n * d_k];
        for step in 0..t {
            let out = ssa.step(&streams.q[step], &streams.k[step], &streams.v[step]);
            let e = ssa_expectation(&streams.q[step], &streams.k[step], &streams.v[step]);
            for i in 0..n * d_k {
                mean[i] += out.attn.get(i / d_k, i % d_k) as u8 as f64 / t as f64;
                expect[i] += e[i] / t as f64;
            }
        }
        let mae: f64 = mean
            .iter()
            .zip(&expect)
            .map(|(m, e)| (m - e).abs())
            .sum::<f64>()
            / (n * d_k) as f64;
        println!("| {t:>18} | {mae:>35.4} |");
    }

    // cost side: stochastic step vs computing the dense expectation
    let mut set = BenchSet::new("ablate_stochastic step cost");
    set.start();
    let c = cfg.with_time_steps(1);
    let streams = SpikeStreams::from_rates(&c, (0.5, 0.5, 0.5), 3);
    let mut ssa = SsaAttention::new(c, PrngSharing::PerRow, 5);
    set.bench("stochastic SSA step (packed bits)", || {
        std::hint::black_box(ssa.step(&streams.q[0], &streams.k[0], &streams.v[0]));
    });
    set.bench("dense expectation (f64 matmuls)", || {
        std::hint::black_box(ssa_expectation(&streams.q[0], &streams.k[0], &streams.v[0]));
    });
    set.finish();
    println!(
        "\nshape: the expectation needs dense multiply-accumulate (the hardware \
         SSA removes); the stochastic path pays an O(1/sqrt(T)) estimator error."
    );
}
