"""AOT compile path: train -> quantize -> lower to HLO text -> serialize.

``python -m compile.aot --out ../artifacts`` produces everything the Rust
binary consumes (and nothing else ever runs Python again):

* ``<variant>.hlo.txt``      — HLO text of the jitted inference graph
  (images + seed + flattened params -> logits).  HLO *text* because the
  ``xla`` crate's xla_extension 0.5.1 rejects jax>=0.5 serialized protos
  (64-bit instruction ids); the text parser reassigns ids.
* ``weights_<arch>.bin``     — trained (INT8-quantize-dequantized) params.
* ``dataset_test.bin``       — the canonical tiny-digits test split.
* ``golden_<variant>.bin``   — logits computed in Python for a fixed
  (batch, seed), letting Rust integration tests assert bit-faithful
  execution of the loaded HLO.
* ``accuracy.json``          — the Table-I sweep measured at train time.
* ``loss_<arch>.csv``        — training loss curves (E2E evidence).
* ``manifest.json``          — index of all of the above.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import struct
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .config import ARCH_ANN, ARCH_SPIKFORMER, ARCH_SSA, ModelConfig, TrainConfig, vit_tiny
from .layers import AOT_MODE, Params

T_SWEEP = (4, 8, 10)
GOLDEN_SEED = 42


# ---------------------------------------------------------------------------
# HLO text lowering (see /opt/xla-example/gen_hlo.py and DESIGN.md §2)
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(cfg: ModelConfig, params: Params, batch: int) -> str:
    """Lower (flattened-params, images, seed) -> (logits,) to HLO text.

    Params are passed as runtime inputs (not baked constants) so the Rust
    router can hot-swap weights without recompiling; flattening order is
    the sorted parameter name list recorded in the manifest.
    """
    names = sorted(params.keys())
    fn = model_mod.make_inference_fn(cfg, AOT_MODE)

    def flat_fn(flat_params, images, seed):
        p = dict(zip(names, flat_params))
        return (fn(p, images, seed),)

    example_params = tuple(
        jax.ShapeDtypeStruct(params[n].shape, jnp.float32) for n in names
    )
    images_spec = jax.ShapeDtypeStruct((batch, cfg.image_size, cfg.image_size), jnp.float32)
    seed_spec = jax.ShapeDtypeStruct((), jnp.uint32)
    # keep_unused: the ANN ignores `seed`; without this jit would DCE the
    # parameter and break the uniform (params, images, seed) runtime ABI.
    lowered = jax.jit(flat_fn, keep_unused=True).lower(
        example_params, images_spec, seed_spec
    )
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# binary serialization shared with rust/src/runtime/weights.rs
# ---------------------------------------------------------------------------

WEIGHTS_MAGIC = 0x53534157  # 'WASS'


def write_weights(path: str, params: Params) -> List[str]:
    """Little-endian: magic, version, count, then per tensor:
    name_len u32 | name utf8 | ndim u32 | dims u32* | f32 data."""
    names = sorted(params.keys())
    with open(path, "wb") as f:
        f.write(struct.pack("<III", WEIGHTS_MAGIC, 1, len(names)))
        for n in names:
            w = np.asarray(params[n], dtype="<f4")
            nb = n.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<I", w.ndim))
            for d in w.shape:
                f.write(struct.pack("<I", d))
            f.write(w.tobytes())
    return names


def write_golden(path: str, logits: np.ndarray, images: np.ndarray, seed: int) -> None:
    """Golden record: images + seed + expected logits for Rust integration
    tests.  Layout: magic, version, batch, image_size, n_classes, seed,
    images f32, logits f32."""
    b, s, _ = images.shape
    c = logits.shape[1]
    with open(path, "wb") as f:
        f.write(struct.pack("<IIIIII", 0x474F4C44, 1, b, s, c, seed))
        f.write(images.astype("<f4").tobytes())
        f.write(logits.astype("<f4").tobytes())


# ---------------------------------------------------------------------------
# main pipeline
# ---------------------------------------------------------------------------


def build(out_dir: str, tcfg: TrainConfig, serve_batch: int = 8) -> None:
    os.makedirs(out_dir, exist_ok=True)
    xtr, ytr, xte, yte = data_mod.train_test(tcfg.n_train, tcfg.n_test)
    data_mod.write_dataset_bin(os.path.join(out_dir, "dataset_test.bin"), xte, yte)

    log: List[str] = []
    accuracy: Dict[str, Dict[str, float]] = {}
    manifest: Dict = {
        "version": 1,
        "image_size": 16,
        "patch_size": 4,
        "n_classes": 10,
        "golden_seed": GOLDEN_SEED,
        "dataset": {"test": "dataset_test.bin", "n": int(len(yte))},
        "variants": [],
    }

    golden_images = xte[:serve_batch]

    for arch in (ARCH_ANN, ARCH_SPIKFORMER, ARCH_SSA):
        cfg = vit_tiny(arch=arch, time_steps=max(T_SWEEP))
        arch_tcfg = (
            tcfg if arch == ARCH_ANN else dataclasses.replace(tcfg, steps=tcfg.snn_steps)
        )
        print(f"=== training {arch} ({arch_tcfg.steps} steps) ===", flush=True)
        params, curve = train_mod.train_model(cfg, arch_tcfg, xtr, ytr, xte, yte, log)
        params = train_mod.maybe_quantize(params, tcfg)

        with open(os.path.join(out_dir, f"loss_{arch}.csv"), "w") as f:
            f.write("step,loss\n")
            for s, l in curve:
                f.write(f"{s},{l:.6f}\n")

        # post-quantization Table-I sweep
        if arch == ARCH_ANN:
            acc = train_mod.evaluate(
                cfg, params, data_mod.patchify(xte, cfg.patch_size), yte, tcfg.batch_size
            )
            accuracy[arch] = {"-": acc}
        else:
            accuracy[arch] = {
                str(t): a
                for t, a in train_mod.accuracy_sweep(
                    cfg, params, xte, yte, tcfg.batch_size, T_SWEEP
                ).items()
            }
        print(f"accuracy[{arch}] = {accuracy[arch]}", flush=True)

        weights_file = f"weights_{arch}.bin"
        names = write_weights(os.path.join(out_dir, weights_file), params)

        # export HLO variants: ANN once; SNNs across the T sweep; plus a
        # batch-1 SSA variant for the latency-sensitive serving path.
        t_values = ["-"] if arch == ARCH_ANN else list(T_SWEEP)
        batches = [serve_batch]
        for t in t_values:
            vcfg = cfg if t == "-" else cfg.with_time_steps(int(t))
            for b in batches + ([1] if (arch == ARCH_SSA and t == max(T_SWEEP)) else []):
                name = vcfg.variant_name() + (f"_b{b}" if b != serve_batch else "")
                hlo_file = f"{name}.hlo.txt"
                print(f"lowering {name} (batch={b}) ...", flush=True)
                hlo = lower_variant(vcfg, params, b)
                with open(os.path.join(out_dir, hlo_file), "w") as f:
                    f.write(hlo)

                # golden logits for the serve-batch variants
                golden_file = None
                if b == serve_batch:
                    fn = model_mod.make_inference_fn(vcfg, AOT_MODE)
                    logits = np.asarray(
                        jax.jit(fn)(params, jnp.asarray(golden_images), jnp.uint32(GOLDEN_SEED))
                    )
                    golden_file = f"golden_{name}.bin"
                    write_golden(
                        os.path.join(out_dir, golden_file), logits, golden_images, GOLDEN_SEED
                    )

                manifest["variants"].append(
                    {
                        "name": name,
                        "arch": arch,
                        "time_steps": 0 if t == "-" else int(t),
                        "batch": b,
                        "hlo": hlo_file,
                        "weights": weights_file,
                        "param_names": names,
                        "golden": golden_file,
                        "inputs": [
                            {"name": "images", "shape": [b, 16, 16], "dtype": "f32"},
                            {"name": "seed", "shape": [], "dtype": "u32"},
                        ],
                        "output": {"shape": [b, 10], "dtype": "f32"},
                    }
                )

    with open(os.path.join(out_dir, "accuracy.json"), "w") as f:
        json.dump(accuracy, f, indent=2)
    with open(os.path.join(out_dir, "train_log.txt"), "w") as f:
        f.write("\n".join(log) + "\n")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"artifacts written to {out_dir}", flush=True)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=TrainConfig.steps)
    ap.add_argument("--quick", action="store_true", help="tiny run for CI smoke")
    args = ap.parse_args(argv)
    tcfg = TrainConfig(steps=args.steps)
    if args.quick:
        tcfg = TrainConfig(steps=30, snn_steps=30, n_train=512, n_test=256, eval_every=30)
    build(args.out, tcfg)


if __name__ == "__main__":
    main()
