"""tiny-digits: a deterministic procedural stand-in for MNIST.

The offline build image has no dataset downloads, so the Table-I-shaped
accuracy experiment (E1) runs on a procedurally generated 10-class digit
task: classic 5x7 bitmap-font glyphs rendered into a 16x16 canvas with a
random integer offset, per-image contrast jitter, pixel dropout, and
additive Gaussian noise.  The task is real enough that attention over
patches matters (digit identity is a global shape property), and hard
enough that accuracy is meaningfully below 100% at low spike counts —
which is exactly the regime Table I probes (accuracy vs time steps T).

Determinism: everything derives from ``numpy.random.Generator(PCG64(seed))``
with fixed per-split seeds.  The test split is exported verbatim into
``artifacts/dataset_test.bin`` by ``aot.py``, so the Rust side never needs
to re-derive it (see DESIGN.md §3 substitutions, S14).
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

# 5x7 bitmap font for digits 0-9 ('#' = ink). The canonical ASCII-art font.
_GLYPHS = {
    0: [" ### ", "#   #", "#  ##", "# # #", "##  #", "#   #", " ### "],
    1: ["  #  ", " ##  ", "  #  ", "  #  ", "  #  ", "  #  ", " ### "],
    2: [" ### ", "#   #", "    #", "   # ", "  #  ", " #   ", "#####"],
    3: [" ### ", "#   #", "    #", "  ## ", "    #", "#   #", " ### "],
    4: ["   # ", "  ## ", " # # ", "#  # ", "#####", "   # ", "   # "],
    5: ["#####", "#    ", "#### ", "    #", "    #", "#   #", " ### "],
    6: [" ### ", "#    ", "#    ", "#### ", "#   #", "#   #", " ### "],
    7: ["#####", "    #", "   # ", "  #  ", "  #  ", "  #  ", "  #  "],
    8: [" ### ", "#   #", "#   #", " ### ", "#   #", "#   #", " ### "],
    9: [" ### ", "#   #", "#   #", " ####", "    #", "    #", " ### "],
}

GLYPH_H, GLYPH_W = 7, 5


def _glyph_array(digit: int) -> np.ndarray:
    rows = _GLYPHS[digit]
    return np.array([[1.0 if c == "#" else 0.0 for c in row] for row in rows], np.float32)


_GLYPH_CACHE = {d: _glyph_array(d) for d in range(10)}


def render_digit(
    digit: int,
    rng: np.random.Generator,
    image_size: int = 16,
    noise_std: float = 0.18,
    dropout: float = 0.12,
) -> np.ndarray:
    """Render one augmented digit into a ``[image_size, image_size]`` float
    image with values clipped to [0, 1] (ready for Bernoulli rate coding)."""
    glyph = _GLYPH_CACHE[digit]
    # integer 2x upscale to 10x14, then random placement on the canvas
    scale = 2
    gh, gw = GLYPH_H * scale, GLYPH_W * scale
    big = np.repeat(np.repeat(glyph, scale, axis=0), scale, axis=1)
    canvas = np.zeros((image_size, image_size), np.float32)
    max_y, max_x = image_size - gh, image_size - gw
    oy = rng.integers(0, max_y + 1)
    ox = rng.integers(0, max_x + 1)
    contrast = rng.uniform(0.65, 1.0)
    canvas[oy : oy + gh, ox : ox + gw] = big * contrast
    # pixel dropout models flaky spiking sensors
    keep = rng.random(canvas.shape) >= dropout
    canvas *= keep
    canvas += rng.normal(0.0, noise_std, canvas.shape).astype(np.float32)
    return np.clip(canvas, 0.0, 1.0)


def make_split(
    n: int, seed: int, image_size: int = 16
) -> Tuple[np.ndarray, np.ndarray]:
    """Generate ``n`` images with balanced labels; returns (X [n,s,s] f32 in
    [0,1], y [n] int32)."""
    rng = np.random.default_rng(np.random.PCG64(seed))
    labels = np.arange(n, dtype=np.int32) % 10
    rng.shuffle(labels)
    images = np.stack([render_digit(int(d), rng, image_size) for d in labels])
    return images.astype(np.float32), labels


def train_test(
    n_train: int, n_test: int, image_size: int = 16
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Canonical E1 splits (seeds fixed: train=0x5A, test=0xA5)."""
    xtr, ytr = make_split(n_train, seed=0x5A, image_size=image_size)
    xte, yte = make_split(n_test, seed=0xA5, image_size=image_size)
    return xtr, ytr, xte, yte


def patchify(images: np.ndarray, patch_size: int) -> np.ndarray:
    """``[B, S, S] -> [B, N, patch_size**2]`` in row-major patch order —
    must match ``rust/src/data`` (the serving example patchifies in Rust)."""
    b, s, _ = images.shape
    p = patch_size
    g = s // p
    x = images.reshape(b, g, p, g, p)
    x = x.transpose(0, 1, 3, 2, 4)  # [B, gy, gx, p, p]
    return x.reshape(b, g * g, p * p)


def write_dataset_bin(path: str, images: np.ndarray, labels: np.ndarray) -> None:
    """Serialize a split for the Rust side.

    Layout (little-endian): magic ``u32=0x534E4454`` ('TDNS'), version u32,
    count u32, image_size u32, then ``count`` records of
    ``image_size**2 f32`` pixels followed by label ``u32``.
    """
    import struct

    n, s, _ = images.shape
    with open(path, "wb") as f:
        f.write(struct.pack("<IIII", 0x534E4454, 1, n, s))
        for i in range(n):
            f.write(images[i].astype("<f4").tobytes())
            f.write(struct.pack("<I", int(labels[i])))
