"""Surrogate-gradient training for the E1 (Table-I-shaped) experiment.

Build-time only: this module never ships to the request path.  It trains
the three ViT-Tiny families (ANN / Spikformer / SSA) on tiny-digits with a
hand-rolled Adam (the offline image carries no optax) and reports accuracy
at T in {4, 8, 10} for the spiking families — the Table I sweep.

The spiking nets are trained once at the largest T and evaluated at the
smaller horizons: rate-coded SNNs degrade gracefully as the Bernoulli
estimate gets fewer samples, which is exactly the accuracy-vs-T shape
Table I reports.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as data_mod
from . import model as model_mod
from .config import ARCH_ANN, ModelConfig, TrainConfig
from .layers import EVAL_MODE, TRAIN_MODE, Params, init_params, quantize_int8


# ---------------------------------------------------------------------------
# optimizer (Adam + decoupled weight decay)
# ---------------------------------------------------------------------------


def adam_init(params: Params) -> Dict:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(
    params: Params,
    grads: Params,
    state: Dict,
    lr: float,
    weight_decay: float,
    b1: float = 0.9,
    b2: float = 0.999,
    eps: float = 1e-8,
) -> Tuple[Params, Dict]:
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1.0 - b1**tf
    bc2 = 1.0 - b2**tf

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * weight_decay * p

    return jax.tree_util.tree_map(upd, params, m, v), {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# loss / metrics
# ---------------------------------------------------------------------------


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Jitted (params, opt, patches, labels, seed) -> (params, opt, loss)."""

    def loss_fn(params, patches, labels, seed):
        logits = model_mod.forward(cfg, params, patches, seed, TRAIN_MODE)
        return cross_entropy(logits, labels)

    total = jnp.float32(max(tcfg.steps, 1))

    @jax.jit
    def step(params, opt, patches, labels, seed):
        loss, grads = jax.value_and_grad(loss_fn)(params, patches, labels, seed)
        # cosine decay to 10% of the base LR over the run
        frac = jnp.minimum(opt["t"].astype(jnp.float32) / total, 1.0)
        lr = tcfg.lr * (0.1 + 0.9 * 0.5 * (1.0 + jnp.cos(jnp.pi * frac)))
        params, opt = adam_update(params, grads, opt, lr, tcfg.weight_decay)
        return params, opt, loss

    return step


def make_eval_fn(cfg: ModelConfig):
    """Jitted batch-accuracy in hard-sampling eval mode."""

    @jax.jit
    def run(params, patches, labels, seed):
        logits = model_mod.forward(cfg, params, patches, seed, EVAL_MODE)
        return jnp.sum(jnp.argmax(logits, axis=-1) == labels)

    return run


def evaluate(
    cfg: ModelConfig, params: Params, patches: np.ndarray, labels: np.ndarray, batch: int, seed: int = 1234
) -> float:
    run = make_eval_fn(cfg)
    correct = 0
    n = len(labels)
    batch = min(batch, n)
    for i in range(0, n - n % batch, batch):
        correct += int(
            run(
                params,
                jnp.asarray(patches[i : i + batch]),
                jnp.asarray(labels[i : i + batch]),
                jnp.uint32(seed + i),
            )
        )
    return correct / (n - n % batch)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def batches(
    x: np.ndarray, y: np.ndarray, batch: int, seed: int
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    n = len(y)
    while True:
        idx = rng.permutation(n)
        for i in range(0, n - n % batch, batch):
            sel = idx[i : i + batch]
            yield x[sel], y[sel]


# ---------------------------------------------------------------------------
# top-level training entry
# ---------------------------------------------------------------------------


def train_model(
    cfg: ModelConfig, tcfg: TrainConfig, xtr: np.ndarray, ytr: np.ndarray,
    xte: np.ndarray, yte: np.ndarray, log: List[str],
) -> Tuple[Params, List[Tuple[int, float]]]:
    """Train one architecture; returns (params, loss_curve)."""
    patches_tr = data_mod.patchify(xtr, cfg.patch_size)
    patches_te = data_mod.patchify(xte, cfg.patch_size)
    params = init_params(cfg, jax.random.PRNGKey(tcfg.seed))
    opt = adam_init(params)
    step_fn = make_train_step(cfg, tcfg)
    it = batches(patches_tr, ytr, tcfg.batch_size, tcfg.seed)

    curve: List[Tuple[int, float]] = []
    t0 = time.time()
    for step in range(1, tcfg.steps + 1):
        bx, by = next(it)
        params, opt, loss = step_fn(
            params, opt, jnp.asarray(bx), jnp.asarray(by), jnp.uint32(step)
        )
        if step % 20 == 0 or step == 1:
            curve.append((step, float(loss)))
        if step % tcfg.eval_every == 0 or step == tcfg.steps:
            acc = evaluate(cfg, params, patches_te, yte, tcfg.batch_size)
            msg = (
                f"[{cfg.variant_name()}] step {step:4d} loss {float(loss):.4f} "
                f"test-acc {acc * 100:.2f}% ({time.time() - t0:.0f}s)"
            )
            print(msg, flush=True)
            log.append(msg)
    return params, curve


def accuracy_sweep(
    cfg: ModelConfig, params: Params, xte: np.ndarray, yte: np.ndarray,
    batch: int, t_values: Tuple[int, ...],
) -> Dict[int, float]:
    """Evaluate a trained spiking model at several time horizons (Table I)."""
    patches = data_mod.patchify(xte, cfg.patch_size)
    out = {}
    for t in t_values:
        out[t] = evaluate(cfg.with_time_steps(t), params, patches, yte, batch)
    return out


def maybe_quantize(params: Params, tcfg: TrainConfig) -> Params:
    return quantize_int8(params) if tcfg.quantize_int8 else params
