"""Pallas kernel for one time step of Stochastic Spiking Attention.

This is the L1 compute hot-spot of the stack: paper eqs. (5)-(6) fused into
a single kernel per (batch, head) grid cell.

Hardware adaptation (DESIGN.md §Hardware-Adaptation)
----------------------------------------------------
The paper's accelerator is an N x N spatial array of AND-gate SAUs that
consumes Q/K/V bit-serially over D_K clock cycles.  On a TPU-shaped target
the same dataflow maps to:

* AND + popcount over D_K  ->  one MXU matmul of {0,1}-float matrices
  (``q @ k^T`` counts exactly the AND coincidences);
* counter + normalizing Bernoulli encoder  ->  VPU compare against a
  uniform tensor (``u < count / D_K``);
* the "hold S while V streams" phase  ->  the second fused matmul
  ``s @ v`` followed by its own comparator stage.

BlockSpec tiles one (head) slice of Q/K/V/S into VMEM per grid step — the
VMEM footprint for the paper's ViT-Small head (N=64, D_K=48) is ~84 KiB,
far under budget, so no inner tiling is needed; the grid iterates over
batch*heads.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is what this path certifies (real-TPU
perf is estimated analytically in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssa_step_kernel(q_ref, k_ref, v_ref, us_ref, ua_ref, out_ref, *, n: int, d_k: int):
    """Fused SSA step for one (batch*head) tile resident in VMEM.

    Refs are blocks of shape [1, N, D_K] (q/k/v/out), [1, N, N] (us),
    [1, N, D_K] (ua); the leading unit axis is the grid axis.
    """
    q = q_ref[0]  # [N, D_K] {0,1} floats
    k = k_ref[0]
    v = v_ref[0]
    # Stage 1 — attention scores, eq. (5): AND-count == binary matmul (MXU),
    # then the Bernoulli encoder bank == comparator against uniforms (VPU).
    counts = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    s = (us_ref[0] < counts * (1.0 / d_k)).astype(jnp.float32)
    # Stage 2 — attention-value product, eq. (6): same SC pattern with the
    # row adders normalizing by N.
    acc = jnp.dot(s, v, preferred_element_type=jnp.float32)
    out_ref[0] = (ua_ref[0] < acc * (1.0 / n)).astype(jnp.float32)


def _ssa_step_kernel_fused(q_ref, k_ref, v_ref, us_ref, ua_ref, out_ref, *, n: int, d_k: int):
    """Single-block variant: the whole [G, N, D_K] batch in one grid cell.

    §Perf L2: under `interpret=True` a (G,) grid lowers to an XLA while
    loop over grid cells — ~0.9 ms/step of loop overhead on the CPU PJRT
    path.  For the small serving geometries the whole batch fits VMEM
    comfortably (see `vmem_bytes`), so the AOT artifacts use this fused
    block; a real-TPU build for ViT-Small-scale models would keep the
    per-head grid (structure preserved in `_ssa_step_kernel`).
    """
    q = q_ref[...]  # [G, N, D_K]
    counts = jax.lax.dot_general(
        q,
        k_ref[...],
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [G, N, N]
    s = (us_ref[...] < counts * (1.0 / d_k)).astype(jnp.float32)
    acc = jax.lax.dot_general(
        s,
        v_ref[...],
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # [G, N, D_K]
    out_ref[...] = (ua_ref[...] < acc * (1.0 / n)).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret", "fused"))
def ssa_attention_step(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    u_score: jnp.ndarray,
    u_attn: jnp.ndarray,
    interpret: bool = True,
    fused: bool = True,
) -> jnp.ndarray:
    """One SSA time step over a stacked ``[G, N, D_K]`` spike batch.

    ``G`` is any flattened leading extent (batch * heads); each grid cell
    processes one G-slice.  Bit-exact against ``ref.ssa_attention_step``
    given identical uniforms (pytest enforces this across a hypothesis
    sweep of shapes).

    Args:
      q, k, v: ``[G, N, D_K]`` float32 holding exactly {0,1}.
      u_score: ``[G, N, N]`` float32 uniforms in [0, 1).
      u_attn:  ``[G, N, D_K]`` float32 uniforms in [0, 1).
      interpret: keep True on CPU PJRT (Mosaic is TPU-only).

    Returns:
      ``[G, N, D_K]`` float32 {0,1}: ``Attn^t``.
    """
    g, n, d_k = q.shape
    if k.shape != (g, n, d_k) or v.shape != (g, n, d_k):
        raise ValueError(f"q/k/v shape mismatch: {q.shape} {k.shape} {v.shape}")
    if u_score.shape != (g, n, n):
        raise ValueError(f"u_score must be [G,N,N], got {u_score.shape}")
    if u_attn.shape != (g, n, d_k):
        raise ValueError(f"u_attn must be [G,N,D_K], got {u_attn.shape}")

    if fused:
        kernel = functools.partial(_ssa_step_kernel_fused, n=n, d_k=d_k)
        blk_nd = pl.BlockSpec((g, n, d_k), lambda: (0, 0, 0))
        blk_nn = pl.BlockSpec((g, n, n), lambda: (0, 0, 0))
        return pl.pallas_call(
            kernel,
            in_specs=[blk_nd, blk_nd, blk_nd, blk_nn, blk_nd],
            out_specs=blk_nd,
            out_shape=jax.ShapeDtypeStruct((g, n, d_k), jnp.float32),
            interpret=interpret,
        )(q, k, v, u_score, u_attn)
    kernel = functools.partial(_ssa_step_kernel, n=n, d_k=d_k)
    blk_nd = pl.BlockSpec((1, n, d_k), lambda i: (i, 0, 0))
    blk_nn = pl.BlockSpec((1, n, n), lambda i: (i, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(g,),
        in_specs=[blk_nd, blk_nd, blk_nd, blk_nn, blk_nd],
        out_specs=blk_nd,
        out_shape=jax.ShapeDtypeStruct((g, n, d_k), jnp.float32),
        interpret=interpret,
    )(q, k, v, u_score, u_attn)


def vmem_bytes(n: int, d_k: int) -> int:
    """Estimated VMEM residency of one grid step (f32), for DESIGN.md §Perf.

    4 [N,D_K] tiles (q, k, v, out) + [N,N] scores/uniform tile + [N,D_K]
    uniform tile + the [N,N] S intermediate.
    """
    f32 = 4
    return f32 * (4 * n * d_k + 2 * n * n + n * d_k)
