"""Pallas kernel for the Bernoulli rate encoder (paper eq. (2)).

In hardware this block is an LFSR PRNG + comparator (paper §III-D); here
the uniforms are explicit kernel inputs and the kernel is the comparator.
Keeping randomness out of the kernel makes every layer of the stack
(bit-)reproducible from a single seed and mirrors the silicon split
between the PRNG and the datapath.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bernoulli_kernel(x_ref, u_ref, out_ref):
    out_ref[...] = (u_ref[...] < x_ref[...]).astype(jnp.float32)


@jax.jit
def bernoulli_encode(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Encode normalized reals ``x`` (in [0,1]) into {0,1} spikes.

    ``x`` and ``u`` must share a 2-D shape ``[G, F]``; returns float32 {0,1}.
    Bit-exact against ``ref.bernoulli_encode``.
    """
    if x.shape != u.shape:
        raise ValueError(f"x/u shape mismatch: {x.shape} vs {u.shape}")
    g, f = x.shape
    blk = pl.BlockSpec((g, f), lambda: (0, 0))
    return pl.pallas_call(
        _bernoulli_kernel,
        in_specs=[blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct((g, f), jnp.float32),
        interpret=True,
    )(x, u)
