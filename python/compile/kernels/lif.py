"""Pallas kernel for a layer of leaky integrate-and-fire (LIF) neurons.

One kernel invocation advances every neuron in a ``[G, F]`` sheet by one
discrete time step (paper §II-C): leak, integrate, threshold, soft reset.
The spiking QKV encoders of eq. (4) are exactly this kernel applied to the
result of the (dense) projection ``X^t W``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_step_kernel(v_ref, i_ref, v_out_ref, s_out_ref, *, beta: float, theta: float):
    """LIF update for one VMEM-resident tile: v' = beta*v + I, fire, reset."""
    v = beta * v_ref[...] + i_ref[...]
    spikes = (v >= theta).astype(jnp.float32)
    v_out_ref[...] = v - theta * spikes
    s_out_ref[...] = spikes


@functools.partial(jax.jit, static_argnames=("beta", "theta", "interpret"))
def lif_step(
    v: jnp.ndarray,
    current: jnp.ndarray,
    beta: float = 0.9,
    theta: float = 1.0,
    interpret: bool = True,
):
    """Advance a LIF neuron sheet one step.

    Args:
      v: membrane potentials, any 2-D float32 shape ``[G, F]``.
      current: input currents, same shape.
      beta: leak factor in [0, 1].
      theta: firing threshold.

    Returns:
      ``(v_next, spikes)`` — both ``[G, F]`` float32, spikes in {0,1}.
      Bit-exact against ``ref.lif_step``.
    """
    if v.shape != current.shape:
        raise ValueError(f"v/current shape mismatch: {v.shape} vs {current.shape}")
    g, f = v.shape
    kernel = functools.partial(_lif_step_kernel, beta=beta, theta=theta)
    blk = pl.BlockSpec((g, f), lambda: (0, 0))
    out_shape = jax.ShapeDtypeStruct((g, f), jnp.float32)
    return pl.pallas_call(
        kernel,
        in_specs=[blk, blk],
        out_specs=(blk, blk),
        out_shape=(out_shape, out_shape),
        interpret=interpret,
    )(v, current)
