"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has a bit-exact oracle here (deterministic
given the same uniform-random inputs).  pytest compares kernel vs oracle
across a hypothesis sweep of shapes/dtypes; these oracles are also what the
L2 model uses when ``use_pallas=False`` (e.g. under ``jax.grad``, where the
interpret-mode kernel would be needlessly slow).

Conventions
-----------
* Spikes are carried as ``float32`` tensors holding exactly 0.0 or 1.0.
  (Binary dtypes do not survive the MXU; the {0,1}-float convention means a
  logical AND across the feature axis is an ordinary matmul — the TPU
  mapping of the paper's AND-gate array, see DESIGN.md §Hardware-Adaptation.)
* All stochasticity enters through explicit uniform tensors in [0, 1);
  a Bernoulli(p) draw is ``u < p``.  This mirrors the hardware, where the
  Bernoulli encoder is an LFSR PRNG + comparator (paper §III-D).
"""

from __future__ import annotations

import jax.numpy as jnp


def bernoulli_encode(x: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """Bernoulli-encode normalized reals ``x`` in [0,1] given uniforms ``u``.

    Paper eq. (2): ``x^t ~ Bern(norm(x))``.  Returns {0,1} float32.
    """
    return (u < x).astype(jnp.float32)


def lif_step(v: jnp.ndarray, current: jnp.ndarray, *, beta: float, theta: float):
    """One step of the discrete leaky integrate-and-fire neuron (paper §II-C).

    ``v' = beta * v + current``; spike where ``v' >= theta``; soft reset by
    subtraction.  Returns ``(v_next, spikes)`` with spikes in {0,1} float32.
    """
    v = beta * v + current
    spikes = (v >= theta).astype(jnp.float32)
    v_next = v - theta * spikes
    return v_next, spikes


def ssa_attention_step(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    u_score: jnp.ndarray,
    u_attn: jnp.ndarray,
) -> jnp.ndarray:
    """One time step of Stochastic Spiking Attention (paper eqs. (5)-(6)).

    Args:
      q, k, v: {0,1} float32 ``[..., N, D_K]`` spike matrices for this step.
      u_score: uniforms ``[..., N, N]`` — the S-stage Bernoulli encoders.
      u_attn:  uniforms ``[..., N, D_K]`` — the Attn-stage encoders.

    Returns {0,1} float32 ``[..., N, D_K]``: ``Attn^t``.

    The AND-and-count of the SAU array is expressed as a matmul of {0,1}
    matrices: ``sum_d q[i,d] AND k[j,d] == (q @ k^T)[i,j]`` exactly.
    """
    d_k = q.shape[-1]
    n = q.shape[-2]
    # S^t_{ij} ~ Bern( (1/D_K) sum_d Q^t_{id} AND K^t_{jd} )      eq. (5)
    scores = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / d_k
    s = (u_score < scores).astype(jnp.float32)
    # Attn^t_{id} ~ Bern( (1/N) sum_j S^t_{ij} AND V^t_{jd} )     eq. (6)
    probs = jnp.matmul(s, v) / n
    return (u_attn < probs).astype(jnp.float32)


def ssa_attention_expectation(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray
) -> jnp.ndarray:
    """E[Attn^t | Q^t, K^t, V^t] — the deterministic mean of eqs. (5)-(6).

    Used by the A4 ablation (stochastic vs expectation) and by the
    expectation-matching tests: conditioned on the spikes, the two Bernoulli
    stages chain, so the mean is the composed normalized product.
    """
    d_k = q.shape[-1]
    n = q.shape[-2]
    s_prob = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / d_k
    return jnp.matmul(s_prob, v) / n


def linear_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Softmax-free linear attention [26] on real-valued inputs.

    ``(Q K^T / D_K) V / N`` — the ANN-domain quantity whose Bernoulli-coded
    estimator SSA computes (Fig. 1 equivalence, experiment E4).
    """
    d_k = q.shape[-1]
    n = q.shape[-2]
    return jnp.matmul(jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / d_k, v) / n


def softmax(x: jnp.ndarray) -> jnp.ndarray:
    """Numerically-stable softmax along the last axis."""
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def softmax_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Standard scaled dot-product attention (paper eq. (1)) — ANN baseline."""
    d_k = q.shape[-1]
    scores = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / jnp.sqrt(jnp.float32(d_k))
    return jnp.matmul(softmax(scores), v)


def spikformer_attention_step(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, scale: float
) -> jnp.ndarray:
    """Spikformer-style spiking self-attention [18] for one time step.

    ``Q^t K^{tT} V^t`` computed with integer arithmetic on spike matrices
    (the multiplier-based baseline that SSA's AND gates replace), scaled.
    The caller passes the result through a LIF layer to re-binarize.
    Returns the real-valued pre-activation ``[..., N, D_K]``.
    """
    return jnp.matmul(jnp.matmul(q, jnp.swapaxes(k, -1, -2)), v) * scale
