"""Model / training / experiment configuration.

Two standard configurations are defined:

* ``vit_tiny()`` — the trainable demo model used for the Table-I-shaped
  accuracy experiment (E1) on the tiny-digits dataset.  Small enough to
  train on one CPU core in minutes, structurally identical to the paper's
  pipeline (Bernoulli input coding -> LIF QKV -> SSA -> spiking MLP).
* ``vit_small_paper()`` — the paper's ViT-Small *attention-block geometry*
  (N=64 tokens, D=384, 8 heads, D_K=48, T=10).  Never trained here; it is
  the configuration at which the energy/latency models (Tables II/III) are
  evaluated, mirroring the paper.

Both N and D_K are powers of two in the demo config, matching the paper's
§III-D hardware simplification (comparator-only Bernoulli encoders).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

ARCH_ANN = "ann"
ARCH_SPIKFORMER = "spikformer"
ARCH_SSA = "ssa"
ARCHS = (ARCH_ANN, ARCH_SPIKFORMER, ARCH_SSA)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters shared by all three model families."""

    arch: str = ARCH_SSA
    image_size: int = 16
    patch_size: int = 4
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    n_classes: int = 10
    d_mlp: int = 128
    # SNN-only parameters
    time_steps: int = 10
    lif_beta: float = 0.9
    lif_theta: float = 1.0
    surrogate_alpha: float = 2.0  # steepness of the sigmoid surrogate
    # Spikformer attention pre-activation scale (their `s`)
    spikformer_scale: float = 0.25

    def __post_init__(self):
        if self.arch not in ARCHS:
            raise ValueError(f"unknown arch {self.arch!r}, expected one of {ARCHS}")
        if self.image_size % self.patch_size:
            raise ValueError("image_size must be divisible by patch_size")
        if self.d_model % self.n_heads:
            raise ValueError("d_model must be divisible by n_heads")

    @property
    def n_tokens(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size

    def variant_name(self) -> str:
        """Artifact-manifest key, e.g. ``ssa_t10``; the ANN has no T."""
        if self.arch == ARCH_ANN:
            return "ann"
        return f"{self.arch}_t{self.time_steps}"

    def with_time_steps(self, t: int) -> "ModelConfig":
        return dataclasses.replace(self, time_steps=t)

    def with_arch(self, arch: str) -> "ModelConfig":
        return dataclasses.replace(self, arch=arch)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Surrogate-gradient training schedule for the E1 accuracy run."""

    steps: int = 600
    # SNNs converge slower under surrogate gradients + SC noise; they get
    # a longer schedule (the ANN keeps `steps`).
    snn_steps: int = 2200
    batch_size: int = 64
    lr: float = 1e-3
    weight_decay: float = 1e-4
    seed: int = 0
    eval_every: int = 100
    n_train: int = 4096
    n_test: int = 1024
    # INT8 post-training weight quantization (paper: "parameters of all
    # three implementations are INT8-quantized")
    quantize_int8: bool = True


def vit_tiny(arch: str = ARCH_SSA, time_steps: int = 10) -> ModelConfig:
    """Demo configuration trained in E1 (Table-I shape)."""
    return ModelConfig(arch=arch, time_steps=time_steps)


def vit_small_paper() -> Tuple[int, int, int, int, int]:
    """Paper's attention-block geometry for Tables II/III:
    ``(n_tokens, d_model, n_heads, d_head, time_steps)``."""
    return (64, 384, 8, 48, 10)
