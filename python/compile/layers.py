"""L2 building blocks: spiking layers with surrogate gradients.

Everything is pure-functional (params and LIF membrane state are explicit
pytrees) so the same code paths serve three uses:

1. **training** — surrogate-gradient mode: Bernoulli draws and LIF
   thresholds use straight-through estimators so ``jax.grad`` flows;
2. **evaluation** — hard {0,1} sampling with the jnp oracle ops;
3. **AOT export** — hard sampling with the *Pallas kernels* from
   ``compile.kernels``; this is the graph lowered to HLO text and executed
   from Rust (the only mode that ever reaches the request path).

The mode is a static ``StochasticMode`` flag compiled into the graph.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .kernels import ref
from .kernels.bernoulli import bernoulli_encode as pallas_bernoulli
from .kernels.lif import lif_step as pallas_lif
from .kernels.ssa_attention import ssa_attention_step as pallas_ssa

Params = Dict[str, jnp.ndarray]
State = Dict[str, jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class StochasticMode:
    """Static compilation mode for the stochastic primitives."""

    surrogate: bool = False  # straight-through gradients (training)
    use_pallas: bool = False  # route hot ops through the L1 kernels (AOT)

    def __post_init__(self):
        if self.surrogate and self.use_pallas:
            raise ValueError("surrogate training runs on the jnp oracle path")


TRAIN_MODE = StochasticMode(surrogate=True, use_pallas=False)
EVAL_MODE = StochasticMode(surrogate=False, use_pallas=False)
AOT_MODE = StochasticMode(surrogate=False, use_pallas=True)


# ---------------------------------------------------------------------------
# stochastic primitives
# ---------------------------------------------------------------------------


def bernoulli(x: jnp.ndarray, u: jnp.ndarray, mode: StochasticMode) -> jnp.ndarray:
    """Bernoulli rate encoding (eq. 2) with optional straight-through grad.

    The straight-through estimator passes d(sample)/dx = 1: the sample is
    an unbiased estimator of x, so the expected pathwise gradient matches
    the gradient of the expectation (standard for SNN rate coding [28]).
    """
    if mode.surrogate:
        hard = (u < x).astype(jnp.float32)
        return x + jax.lax.stop_gradient(hard - x)
    if mode.use_pallas:
        flat = x.reshape(-1, x.shape[-1])
        out = pallas_bernoulli(flat, u.reshape(flat.shape))
        return out.reshape(x.shape)
    return ref.bernoulli_encode(x, u)


def lif(
    v: jnp.ndarray, current: jnp.ndarray, cfg: ModelConfig, mode: StochasticMode
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """LIF neuron sheet step (paper §II-C) with sigmoid surrogate in training.

    Returns ``(v_next, spikes)``.
    """
    if mode.surrogate:
        v = cfg.lif_beta * v + current
        hard = (v >= cfg.lif_theta).astype(jnp.float32)
        sur = jax.nn.sigmoid(cfg.surrogate_alpha * (v - cfg.lif_theta))
        spikes = sur + jax.lax.stop_gradient(hard - sur)
        # reset uses the hard spike (what the hardware does); gradient flows
        # through the surrogate via the spikes term only.
        v_next = v - cfg.lif_theta * jax.lax.stop_gradient(hard)
        return v_next, spikes
    if mode.use_pallas:
        shape = v.shape
        flat_v = v.reshape(-1, shape[-1])
        flat_i = current.reshape(flat_v.shape)
        v2, s = pallas_lif(flat_v, flat_i, beta=cfg.lif_beta, theta=cfg.lif_theta)
        return v2.reshape(shape), s.reshape(shape)
    return ref.lif_step(v, current, beta=cfg.lif_beta, theta=cfg.lif_theta)


def ssa_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    u_score: jnp.ndarray,
    u_attn: jnp.ndarray,
    mode: StochasticMode,
) -> jnp.ndarray:
    """SSA step (eqs. 5-6) over ``[B, H, N, D_K]`` spike tensors.

    Training mode chains two straight-through Bernoulli stages so gradients
    reach Q/K/V through the score probabilities — the surrogate recipe the
    paper inherits from [28].
    """
    b, h, n, d_k = q.shape
    if mode.surrogate:
        scores = jnp.matmul(q, jnp.swapaxes(k, -1, -2)) / d_k
        s_hard = (u_score < scores).astype(jnp.float32)
        s = scores + jax.lax.stop_gradient(s_hard - scores)
        probs = jnp.matmul(s, v) / n
        a_hard = (u_attn < probs).astype(jnp.float32)
        return probs + jax.lax.stop_gradient(a_hard - probs)
    if mode.use_pallas:
        g = b * h
        out = pallas_ssa(
            q.reshape(g, n, d_k),
            k.reshape(g, n, d_k),
            v.reshape(g, n, d_k),
            u_score.reshape(g, n, n),
            u_attn.reshape(g, n, d_k),
        )
        return out.reshape(b, h, n, d_k)
    return ref.ssa_attention_step(q, k, v, u_score, u_attn)


# ---------------------------------------------------------------------------
# parameter initialization
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in: int, fan_out: int) -> jnp.ndarray:
    scale = jnp.sqrt(2.0 / fan_in)
    return scale * jax.random.normal(key, (fan_in, fan_out), jnp.float32)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Initialize the full parameter pytree for any of the three archs.

    All three families share the same parameter names/shapes so the INT8
    quantizer, the weights serializer, and the energy model see a single
    layout (the paper compares the three at matched dimensions).
    """
    params: Params = {}
    n_keys = 4 + 6 * cfg.n_layers
    keys = iter(jax.random.split(key, n_keys))
    params["embed/w"] = _dense_init(next(keys), cfg.patch_dim, cfg.d_model)
    params["embed/pos"] = 0.02 * jax.random.normal(
        next(keys), (cfg.n_tokens, cfg.d_model), jnp.float32
    )
    for l in range(cfg.n_layers):
        p = f"layer{l}/"
        params[p + "wq"] = _dense_init(next(keys), cfg.d_model, cfg.d_model)
        params[p + "wk"] = _dense_init(next(keys), cfg.d_model, cfg.d_model)
        params[p + "wv"] = _dense_init(next(keys), cfg.d_model, cfg.d_model)
        params[p + "wo"] = _dense_init(next(keys), cfg.d_model, cfg.d_model)
        params[p + "w1"] = _dense_init(next(keys), cfg.d_model, cfg.d_mlp)
        params[p + "w2"] = _dense_init(next(keys), cfg.d_mlp, cfg.d_model)
    params["head/w"] = _dense_init(next(keys), cfg.d_model, cfg.n_classes)
    return params


def quantize_int8(params: Params) -> Params:
    """Symmetric per-tensor INT8 quantize-dequantize (paper §IV: parameters
    of all three implementations are INT8-quantized)."""
    out = {}
    for name, w in params.items():
        amax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8)
        scale = amax / 127.0
        out[name] = jnp.clip(jnp.round(w / scale), -127, 127) * scale
    return out


# ---------------------------------------------------------------------------
# heads reshape helpers
# ---------------------------------------------------------------------------


def split_heads(x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """``[B, N, D] -> [B, H, N, D_K]``"""
    b, n, d = x.shape
    return x.reshape(b, n, n_heads, d // n_heads).transpose(0, 2, 1, 3)


def merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """``[B, H, N, D_K] -> [B, N, D]``"""
    b, h, n, d_k = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, n, h * d_k)
