"""L2 models: the spiking ViT family (SSA / Spikformer) and the ANN baseline.

The spiking forward pass follows the paper's pipeline end to end:

  image -> patchify -> Bernoulli rate coding (eq. 2, per time step)
        -> spiking patch embedding (LIF)
        -> [encoder layer] x L:
             Q/K/V = LIF(E^t W_{q,k,v})          (eq. 4, as in [18])
             SSA   = Bern(Bern(QK^T/D_K) V / N)  (eqs. 5-6)   | Spikformer:
                                                  LIF(s * Q K^T V)
             residual merge in the current domain -> LIF
             spiking MLP with residual current   -> LIF
        -> spike-count readout accumulated over T -> logits

Time is driven by ``jax.lax.scan`` (compile-size-friendly; the unrolled
variant is the L2 perf ablation, see EXPERIMENTS.md §Perf).  All
stochasticity derives from a single ``seed`` scalar via ``fold_in``, so
the Rust runtime fully controls reproducibility.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers
from .config import ARCH_ANN, ARCH_SPIKFORMER, ARCH_SSA, ModelConfig
from .kernels import ref
from .layers import Params, StochasticMode


# ---------------------------------------------------------------------------
# spiking forward
# ---------------------------------------------------------------------------


def _init_state(cfg: ModelConfig, batch: int) -> Dict[str, jnp.ndarray]:
    """Zero membrane potentials for every LIF site in the network."""
    n, d, m = cfg.n_tokens, cfg.d_model, cfg.d_mlp
    state = {"embed": jnp.zeros((batch, n, d))}
    for l in range(cfg.n_layers):
        p = f"layer{l}/"
        for name in ("q", "k", "v"):
            state[p + name] = jnp.zeros((batch, n, d))
        state[p + "attn"] = jnp.zeros((batch, n, d))  # spikformer re-binarizer
        state[p + "res"] = jnp.zeros((batch, n, d))
        state[p + "mlp1"] = jnp.zeros((batch, n, m))
        state[p + "mlp2"] = jnp.zeros((batch, n, d))
    return state


def _spiking_step(
    cfg: ModelConfig,
    params: Params,
    mode: StochasticMode,
    patches: jnp.ndarray,  # [B, N, P] in [0,1]
    state: Dict[str, jnp.ndarray],
    key: jax.Array,
) -> Tuple[Dict[str, jnp.ndarray], jnp.ndarray]:
    """One network-wide time step; returns (new_state, per-class logits)."""
    b, n, _ = patches.shape
    h, d_k = cfg.n_heads, cfg.d_head
    new_state = {}
    key_in, key_attn = jax.random.split(key)

    # --- input rate coding (eq. 2) + spiking patch embedding -------------
    u_in = jax.random.uniform(key_in, patches.shape)
    x_t = layers.bernoulli(patches, u_in, mode)  # {0,1} [B,N,P]
    emb_cur = jnp.matmul(x_t, params["embed/w"]) + params["embed/pos"]
    new_state["embed"], spikes = layers.lif(state["embed"], emb_cur, cfg, mode)

    # --- encoder layers ----------------------------------------------------
    for l in range(cfg.n_layers):
        p = f"layer{l}/"
        kq, kk = jax.random.split(jax.random.fold_in(key_attn, l))

        # eq. (4): Q^t, K^t, V^t through per-projection LIF layers
        new_state[p + "q"], q_s = layers.lif(
            state[p + "q"], jnp.matmul(spikes, params[p + "wq"]), cfg, mode
        )
        new_state[p + "k"], k_s = layers.lif(
            state[p + "k"], jnp.matmul(spikes, params[p + "wk"]), cfg, mode
        )
        new_state[p + "v"], v_s = layers.lif(
            state[p + "v"], jnp.matmul(spikes, params[p + "wv"]), cfg, mode
        )
        qh = layers.split_heads(q_s, h)
        kh = layers.split_heads(k_s, h)
        vh = layers.split_heads(v_s, h)

        if cfg.arch == ARCH_SSA:
            u_score = jax.random.uniform(kq, (b, h, n, n))
            u_attn = jax.random.uniform(kk, (b, h, n, d_k))
            attn = layers.ssa_attention(qh, kh, vh, u_score, u_attn, mode)
            attn_spikes = layers.merge_heads(attn)
            new_state[p + "attn"] = state[p + "attn"]  # unused site
        elif cfg.arch == ARCH_SPIKFORMER:
            pre = ref.spikformer_attention_step(qh, kh, vh, cfg.spikformer_scale)
            new_state[p + "attn"], attn_spikes = layers.lif(
                state[p + "attn"], layers.merge_heads(pre), cfg, mode
            )
        else:  # pragma: no cover - guarded by config validation
            raise ValueError(cfg.arch)

        # residual merge in the current domain, then re-binarize (SEW-style)
        res_cur = jnp.matmul(attn_spikes, params[p + "wo"]) + spikes
        new_state[p + "res"], res_spikes = layers.lif(state[p + "res"], res_cur, cfg, mode)

        # spiking MLP with residual current
        new_state[p + "mlp1"], m1 = layers.lif(
            state[p + "mlp1"], jnp.matmul(res_spikes, params[p + "w1"]), cfg, mode
        )
        mlp_cur = jnp.matmul(m1, params[p + "w2"]) + res_spikes
        new_state[p + "mlp2"], spikes = layers.lif(state[p + "mlp2"], mlp_cur, cfg, mode)

    # --- readout: mean-pooled spike counts -> class currents ---------------
    pooled = jnp.mean(spikes, axis=1)  # [B, D]
    logits_t = jnp.matmul(pooled, params["head/w"])
    return new_state, logits_t


def spiking_forward(
    cfg: ModelConfig,
    params: Params,
    patches: jnp.ndarray,
    seed: jnp.ndarray,
    mode: StochasticMode,
) -> jnp.ndarray:
    """Run T time steps; logits are the time-average of per-step readouts."""
    b = patches.shape[0]
    state0 = _init_state(cfg, b)
    base = jax.random.PRNGKey(seed)

    def step(state, t):
        key = jax.random.fold_in(base, t)
        state, logits_t = _spiking_step(cfg, params, mode, patches, state, key)
        return state, logits_t

    _, logits_all = jax.lax.scan(step, state0, jnp.arange(cfg.time_steps))
    return jnp.mean(logits_all, axis=0)


# ---------------------------------------------------------------------------
# ANN baseline
# ---------------------------------------------------------------------------


def ann_forward(cfg: ModelConfig, params: Params, patches: jnp.ndarray) -> jnp.ndarray:
    """Conventional ViT baseline (eq. 1 softmax attention, ReLU MLP).

    Uses the same parameter layout; no normalization layers so that the
    spiking and ANN families differ only in the attention/activation
    mechanism under study (the Table-I comparison axis).
    """
    x = jnp.matmul(patches, params["embed/w"]) + params["embed/pos"]
    for l in range(cfg.n_layers):
        p = f"layer{l}/"
        q = layers.split_heads(jnp.matmul(x, params[p + "wq"]), cfg.n_heads)
        k = layers.split_heads(jnp.matmul(x, params[p + "wk"]), cfg.n_heads)
        v = layers.split_heads(jnp.matmul(x, params[p + "wv"]), cfg.n_heads)
        attn = layers.merge_heads(ref.softmax_attention(q, k, v))
        x = x + jnp.matmul(attn, params[p + "wo"])
        hidden = jax.nn.relu(jnp.matmul(x, params[p + "w1"]))
        x = x + jnp.matmul(hidden, params[p + "w2"])
    pooled = jnp.mean(x, axis=1)
    return jnp.matmul(pooled, params["head/w"])


# ---------------------------------------------------------------------------
# unified entry points
# ---------------------------------------------------------------------------


def forward(
    cfg: ModelConfig,
    params: Params,
    patches: jnp.ndarray,
    seed: jnp.ndarray,
    mode: StochasticMode,
) -> jnp.ndarray:
    """Dispatch on architecture; ``seed`` is ignored by the ANN."""
    if cfg.arch == ARCH_ANN:
        return ann_forward(cfg, params, patches)
    return spiking_forward(cfg, params, patches, seed, mode)


def make_inference_fn(cfg: ModelConfig, mode: StochasticMode = layers.AOT_MODE):
    """Build the (params, images, seed) -> logits function lowered by aot.py.

    Takes raw ``[B, S, S]`` images so the HLO graph owns patchification —
    the Rust side feeds unprocessed pixels.
    """
    from .data import patchify  # numpy twin; jnp re-implementation below

    del patchify

    def fn(params: Params, images: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
        b, s, _ = images.shape
        p = cfg.patch_size
        g = s // p
        x = images.reshape(b, g, p, g, p).transpose(0, 1, 3, 2, 4)
        patches = x.reshape(b, g * g, p * p)
        return forward(cfg, params, patches, seed, mode)

    return fn
