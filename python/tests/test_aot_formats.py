"""AOT serialization formats + lowering: weights/golden binary layouts,
manifest schema, and HLO-text lowering of a tiny variant."""

import json
import struct

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.config import vit_tiny
from compile.layers import init_params


def test_weights_file_layout(tmp_path):
    params = {"b": jnp.ones((2, 3)), "a": jnp.zeros((4,))}
    path = tmp_path / "w.bin"
    names = aot.write_weights(str(path), params)
    assert names == ["a", "b"]  # sorted order is the ABI
    raw = path.read_bytes()
    magic, version, count = struct.unpack_from("<III", raw, 0)
    assert magic == aot.WEIGHTS_MAGIC and version == 1 and count == 2
    # first record is "a": name_len=1, 'a', ndim=1, dim=4, 4 f32
    off = 12
    (name_len,) = struct.unpack_from("<I", raw, off)
    assert name_len == 1 and raw[off + 4 : off + 5] == b"a"


def test_golden_file_layout(tmp_path):
    images = np.random.default_rng(0).random((2, 16, 16)).astype(np.float32)
    logits = np.arange(20, dtype=np.float32).reshape(2, 10)
    path = tmp_path / "g.bin"
    aot.write_golden(str(path), logits, images, seed=99)
    raw = path.read_bytes()
    magic, version, b, s, c, seed = struct.unpack_from("<IIIIII", raw, 0)
    assert (magic, version, b, s, c, seed) == (0x474F4C44, 1, 2, 16, 10, 99)
    tail = np.frombuffer(raw, dtype="<f4", offset=24 + 2 * 16 * 16 * 4)
    np.testing.assert_array_equal(tail.reshape(2, 10), logits)


def test_lower_variant_produces_parseable_hlo():
    cfg = vit_tiny("ssa", 2)
    params = init_params(cfg, jax.random.PRNGKey(0))
    hlo = aot.lower_variant(cfg, params, batch=1)
    # HLO text module header + an entry computation with our input count
    assert hlo.startswith("HloModule"), hlo[:64]
    assert "ENTRY" in hlo
    # params (sorted) + images + seed parameters all appear
    n_inputs = len(params) + 2
    assert hlo.count("parameter(") >= n_inputs


def test_lowered_ann_has_no_rng_ops():
    """The ANN graph must be seed-independent: no rng/bitcast-threefry."""
    cfg = vit_tiny("ann")
    params = init_params(cfg, jax.random.PRNGKey(0))
    hlo = aot.lower_variant(cfg, params, batch=1)
    assert "rng" not in hlo.lower() or "rng-get-and-update-state" not in hlo


def test_manifest_schema_quick(tmp_path):
    """Run the full (quick) build end-to-end and validate the manifest."""
    from compile.config import TrainConfig

    out = tmp_path / "artifacts"
    tcfg = TrainConfig(steps=2, snn_steps=2, n_train=64, n_test=32, eval_every=100)
    aot.build(str(out), tcfg)
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["image_size"] == 16
    names = {v["name"] for v in manifest["variants"]}
    assert {"ann", "spikformer_t10", "ssa_t4", "ssa_t8", "ssa_t10", "ssa_t10_b1"} <= names
    for v in manifest["variants"]:
        assert (out / v["hlo"]).exists(), v["name"]
        assert (out / v["weights"]).exists()
        if v["golden"]:
            assert (out / v["golden"]).exists()
        assert v["param_names"] == sorted(v["param_names"])
    assert (out / "accuracy.json").exists()
    assert (out / "dataset_test.bin").exists()
