"""L1 correctness: Bernoulli encoder kernel vs oracle + rate statistics."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.bernoulli import bernoulli_encode


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    g=st.integers(1, 32),
    f=st.sampled_from([1, 2, 16, 256]),
)
def test_kernel_matches_ref(seed, g, f):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.uniform(k1, (g, f))
    u = jax.random.uniform(k2, (g, f))
    np.testing.assert_array_equal(
        np.asarray(bernoulli_encode(x, u)), np.asarray(ref.bernoulli_encode(x, u))
    )


def test_rate_statistics():
    """Empirical spike rate over T draws converges to the encoded value —
    the defining property of rate coding (paper eq. (2))."""
    x = jnp.array([[0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 1.0]])
    t = 8000
    key = jax.random.PRNGKey(0)
    total = np.zeros_like(np.asarray(x))
    for i in range(t):
        key, sub = jax.random.split(key)
        u = jax.random.uniform(sub, x.shape)
        total += np.asarray(ref.bernoulli_encode(x, u))
    rate = total / t
    np.testing.assert_allclose(rate, np.asarray(x), atol=3 * 0.5 / np.sqrt(t) + 5e-3)


def test_endpoints_deterministic():
    """x=0 never fires; x=1 always fires (u drawn from [0,1))."""
    u = jax.random.uniform(jax.random.PRNGKey(1), (4, 64))
    zeros = bernoulli_encode(jnp.zeros((4, 64)), u)
    ones = bernoulli_encode(jnp.ones((4, 64)), u)
    assert float(jnp.sum(zeros)) == 0.0
    assert float(jnp.sum(ones)) == 4 * 64


def test_sc_multiplication_property():
    """Eq. (3): AND of two independent Bernoulli streams multiplies rates."""
    p1, p2, t = 0.6, 0.7, 20000
    key = jax.random.PRNGKey(2)
    k1, k2 = jax.random.split(key)
    a = jax.random.bernoulli(k1, p1, (t,)).astype(jnp.float32)
    b = jax.random.bernoulli(k2, p2, (t,)).astype(jnp.float32)
    rate = float(jnp.mean(a * b))  # AND of {0,1} == product
    assert abs(rate - p1 * p2) < 3 * 0.5 / np.sqrt(t) + 5e-3
