"""L2 model invariants: mode equivalences, spiking dynamics, quantization,
and architecture dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import data, layers, model
from compile.config import vit_tiny
from compile.layers import AOT_MODE, EVAL_MODE, TRAIN_MODE, init_params, quantize_int8


@pytest.fixture(scope="module")
def patches():
    x, _ = data.make_split(8, seed=1)
    return jnp.asarray(data.patchify(x, 4))


def test_aot_mode_bit_equals_eval_mode(patches):
    """The Pallas path (AOT) and the jnp oracle path (EVAL) must agree
    bitwise — this is what makes the golden files meaningful."""
    for arch in ("ssa", "spikformer"):
        cfg = vit_tiny(arch, 4)
        p = init_params(cfg, jax.random.PRNGKey(0))
        a = model.forward(cfg, p, patches, jnp.uint32(3), AOT_MODE)
        b = model.forward(cfg, p, patches, jnp.uint32(3), EVAL_MODE)
        assert bool(jnp.all(a == b)), arch


def test_seed_changes_stochastic_output(patches):
    cfg = vit_tiny("ssa", 4)
    p = init_params(cfg, jax.random.PRNGKey(0))
    a = model.forward(cfg, p, patches, jnp.uint32(1), EVAL_MODE)
    b = model.forward(cfg, p, patches, jnp.uint32(2), EVAL_MODE)
    assert not bool(jnp.all(a == b))


def test_ann_is_deterministic(patches):
    cfg = vit_tiny("ann")
    p = init_params(cfg, jax.random.PRNGKey(0))
    a = model.forward(cfg, p, patches, jnp.uint32(1), EVAL_MODE)
    b = model.forward(cfg, p, patches, jnp.uint32(2), EVAL_MODE)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_mode_is_differentiable(patches):
    cfg = vit_tiny("ssa", 2)
    p = init_params(cfg, jax.random.PRNGKey(0))

    def loss(pp):
        logits = model.forward(cfg, pp, patches, jnp.uint32(0), TRAIN_MODE)
        return jnp.mean(logits**2)

    grads = jax.grad(loss)(p)
    norms = {k: float(jnp.sum(jnp.abs(v))) for k, v in grads.items()}
    # every parameter tensor must receive gradient signal
    zero = [k for k, n in norms.items() if n == 0.0]
    assert not zero, f"dead gradients: {zero}"


def test_more_time_steps_reduce_logit_noise(patches):
    """Averaged readout over more steps -> lower variance across seeds."""
    p = init_params(vit_tiny("ssa", 1), jax.random.PRNGKey(0))

    def spread(t):
        cfg = vit_tiny("ssa", t)
        outs = [
            np.asarray(model.forward(cfg, p, patches, jnp.uint32(s), EVAL_MODE))
            for s in range(6)
        ]
        return np.std(np.stack(outs), axis=0).mean()

    assert spread(8) < spread(1)


def test_quantize_int8_bounded_error_and_idempotent():
    cfg = vit_tiny("ssa", 2)
    p = init_params(cfg, jax.random.PRNGKey(1))
    q = quantize_int8(p)
    for name in p:
        w, wq = np.asarray(p[name]), np.asarray(q[name])
        scale = np.abs(w).max() / 127.0
        assert np.abs(w - wq).max() <= scale / 2 + 1e-7, name
    q2 = quantize_int8(q)
    for name in q:
        np.testing.assert_allclose(np.asarray(q[name]), np.asarray(q2[name]), atol=1e-7)


def test_spike_rates_are_plausible(patches):
    """Post-LIF Q/K/V rates feed the energy model's activity factors; they
    must be genuine spiking activity (not silent, not saturated)."""
    cfg = vit_tiny("ssa", 8)
    p = init_params(cfg, jax.random.PRNGKey(0))
    logits = model.forward(cfg, p, patches, jnp.uint32(0), EVAL_MODE)
    assert np.isfinite(np.asarray(logits)).all()


def test_param_layout_shared_across_archs():
    keys = None
    for arch in ("ann", "spikformer", "ssa"):
        p = init_params(vit_tiny(arch, 4), jax.random.PRNGKey(0))
        names = sorted(p.keys())
        if keys is None:
            keys = names
        assert names == keys, arch


def test_invalid_config_rejected():
    with pytest.raises(ValueError):
        vit_tiny("nope", 4)
    with pytest.raises(ValueError):
        layers.StochasticMode(surrogate=True, use_pallas=True)
