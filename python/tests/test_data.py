"""tiny-digits dataset: determinism, normalization, patchify layout."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import data


def test_split_is_deterministic():
    x1, y1 = data.make_split(64, seed=7)
    x2, y2 = data.make_split(64, seed=7)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_different_seeds_differ():
    x1, _ = data.make_split(64, seed=1)
    x2, _ = data.make_split(64, seed=2)
    assert not np.array_equal(x1, x2)


def test_values_normalized_and_balanced():
    x, y = data.make_split(200, seed=3)
    assert x.min() >= 0.0 and x.max() <= 1.0
    counts = np.bincount(y, minlength=10)
    assert counts.min() >= 200 // 10 - 1


def test_canonical_split_seeds_are_fixed():
    xtr, _, xte, _ = data.train_test(32, 32)
    xtr2, _, xte2, _ = data.train_test(32, 32)
    np.testing.assert_array_equal(xtr, xtr2)
    np.testing.assert_array_equal(xte, xte2)
    assert not np.array_equal(xtr, xte)


def test_glyphs_are_distinguishable():
    """Mean images per class should differ pairwise — the task is 10-way."""
    x, y = data.make_split(500, seed=5)
    means = np.stack([x[y == c].mean(axis=0) for c in range(10)])
    for a in range(10):
        for b in range(a + 1, 10):
            assert np.abs(means[a] - means[b]).mean() > 0.01, (a, b)


@settings(max_examples=20, deadline=None)
@given(b=st.integers(1, 8), s=st.sampled_from([8, 16]), p=st.sampled_from([2, 4]))
def test_patchify_shape_and_content(b, s, p):
    imgs = np.arange(b * s * s, dtype=np.float32).reshape(b, s, s)
    patches = data.patchify(imgs, p)
    g = s // p
    assert patches.shape == (b, g * g, p * p)
    # first patch of first image == top-left pxp block, row-major
    np.testing.assert_array_equal(
        patches[0, 0], imgs[0, :p, :p].reshape(-1)
    )
    # last patch == bottom-right block
    np.testing.assert_array_equal(
        patches[0, -1], imgs[0, s - p :, s - p :].reshape(-1)
    )


def test_dataset_bin_roundtrip(tmp_path):
    import struct

    x, y = data.make_split(5, seed=9)
    path = tmp_path / "ds.bin"
    data.write_dataset_bin(str(path), x, y)
    raw = path.read_bytes()
    magic, version, n, s = struct.unpack_from("<IIII", raw, 0)
    assert magic == 0x534E4454 and version == 1 and n == 5 and s == 16
    # first image round-trips
    first = np.frombuffer(raw, dtype="<f4", count=s * s, offset=16)
    np.testing.assert_allclose(first.reshape(s, s), x[0])
