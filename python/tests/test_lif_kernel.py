"""L1 correctness: Pallas LIF kernel vs oracle, plus LIF invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.lif import lif_step


def _inputs(seed, g, f):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    v = jax.random.uniform(k1, (g, f), minval=-1.0, maxval=1.0)
    cur = jax.random.normal(k2, (g, f))
    return v, cur


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    g=st.integers(1, 16),
    f=st.sampled_from([1, 3, 16, 64, 128]),
    beta=st.sampled_from([0.0, 0.5, 0.9, 1.0]),
    theta=st.sampled_from([0.5, 1.0, 2.0]),
)
def test_kernel_matches_ref(seed, g, f, beta, theta):
    """Sweep shapes/params: spikes exact away from the threshold knife-edge,
    membrane within 1 ULP (XLA may fuse beta*v+I into an fma)."""
    v, cur = _inputs(seed, g, f)
    v1, s1 = lif_step(v, cur, beta=beta, theta=theta)
    v2, s2 = ref.lif_step(v, cur, beta=beta, theta=theta)
    v1, s1, v2, s2 = map(np.asarray, (v1, s1, v2, s2))
    np.testing.assert_allclose(v1, v2, atol=1e-5)
    pre = beta * np.asarray(v) + np.asarray(cur)
    safe = np.abs(pre - theta) > 1e-5
    np.testing.assert_array_equal(s1[safe], s2[safe])


def test_spikes_are_binary_and_reset_subtracts():
    v, cur = _inputs(0, 8, 32)
    v1, s1 = lif_step(v, cur, beta=0.9, theta=1.0)
    s = np.asarray(s1)
    assert set(np.unique(s)).issubset({0.0, 1.0})
    # where a spike fired, post-reset membrane dropped by exactly theta
    pre = 0.9 * np.asarray(v) + np.asarray(cur)
    np.testing.assert_allclose(np.asarray(v1), pre - 1.0 * s, atol=1e-5)


def test_no_input_no_spikes_with_leak():
    """Sub-threshold membranes decay toward zero and never fire."""
    v = jnp.full((4, 4), 0.5)
    zero = jnp.zeros((4, 4))
    for _ in range(10):
        v, s = lif_step(v, zero, beta=0.5, theta=1.0)
        assert float(jnp.sum(s)) == 0.0
    assert float(jnp.max(jnp.abs(v))) < 1e-3


def test_constant_drive_fires_at_rate():
    """DC current I with beta=0 fires every ceil(theta/I) steps on average:
    with I=0.5, theta=1.0 the neuron spikes exactly every 2nd step."""
    v = jnp.zeros((1, 1))
    cur = jnp.full((1, 1), 0.5)
    fired = []
    for _ in range(10):
        v, s = lif_step(v, cur, beta=1.0, theta=1.0)
        fired.append(int(s[0, 0]))
    assert fired == [0, 1] * 5
