"""L1 correctness: Pallas SSA kernel vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path: the kernel must be
bit-exact against ``ref.ssa_attention_step`` for identical uniforms, and
its sample mean must converge to the linear-attention expectation (the
Fig. 1 / E4 equivalence claim of the paper).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.ssa_attention import ssa_attention_step, vmem_bytes


def _spikes(key, shape, rate):
    return jax.random.bernoulli(key, rate, shape).astype(jnp.float32)


def _setup(seed, g, n, d_k, rates=(0.4, 0.5, 0.6)):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = _spikes(ks[0], (g, n, d_k), rates[0])
    k = _spikes(ks[1], (g, n, d_k), rates[1])
    v = _spikes(ks[2], (g, n, d_k), rates[2])
    us = jax.random.uniform(ks[3], (g, n, n))
    ua = jax.random.uniform(ks[4], (g, n, d_k))
    return q, k, v, us, ua


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    g=st.integers(1, 6),
    n=st.sampled_from([1, 2, 4, 8, 16, 64]),
    d_k=st.sampled_from([1, 2, 8, 16, 48]),
)
def test_kernel_matches_ref_bit_exact(seed, g, n, d_k):
    """Hypothesis sweep over shapes: kernel == oracle, every bit."""
    q, k, v, us, ua = _setup(seed, g, n, d_k)
    out_kernel = ssa_attention_step(q, k, v, us, ua)
    out_ref = ref.ssa_attention_step(q, k, v, us, ua)
    np.testing.assert_array_equal(np.asarray(out_kernel), np.asarray(out_ref))


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    rate_q=st.floats(0.0, 1.0),
    rate_k=st.floats(0.0, 1.0),
)
def test_kernel_output_is_binary(seed, rate_q, rate_k):
    q, k, v, us, ua = _setup(seed, 2, 8, 16, rates=(rate_q, rate_k, 0.5))
    out = np.asarray(ssa_attention_step(q, k, v, us, ua))
    assert set(np.unique(out)).issubset({0.0, 1.0})


def test_all_zero_inputs_give_zero_output():
    """p=0 edge: no coincidences -> S prob 0 -> Attn prob 0 -> no spikes."""
    g, n, d_k = 2, 8, 16
    z = jnp.zeros((g, n, d_k))
    us = jax.random.uniform(jax.random.PRNGKey(0), (g, n, n))
    ua = jax.random.uniform(jax.random.PRNGKey(1), (g, n, d_k))
    out = np.asarray(ssa_attention_step(z, z, z, us, ua))
    assert out.sum() == 0.0


def test_all_one_inputs_give_all_ones():
    """p=1 edge: counts saturate, prob 1 > every uniform in [0,1)."""
    g, n, d_k = 2, 8, 16
    o = jnp.ones((g, n, d_k))
    us = jax.random.uniform(jax.random.PRNGKey(0), (g, n, n))
    ua = jax.random.uniform(jax.random.PRNGKey(1), (g, n, d_k))
    out = np.asarray(ssa_attention_step(o, o, o, us, ua))
    assert out.sum() == out.size


def test_expectation_matches_linear_attention():
    """E4 / Fig. 1: the SSA sample mean estimates linear attention.

    Conditioned on fixed binary Q,K,V, E[Attn^t] over the encoder
    randomness is (QK^T/D_K)(V)/N composed per eqs. (5)-(6); averaging
    many independent uniform draws must converge at the Monte-Carlo rate.
    """
    g, n, d_k, trials = 1, 8, 16, 4000
    q, k, v, _, _ = _setup(7, g, n, d_k)
    expect = np.asarray(ref.ssa_attention_expectation(q, k, v))

    key = jax.random.PRNGKey(123)

    def one(carry_key, _):
        key, k1, k2 = jax.random.split(carry_key, 3)
        us = jax.random.uniform(k1, (g, n, n))
        ua = jax.random.uniform(k2, (g, n, d_k))
        return key, ref.ssa_attention_step(q, k, v, us, ua)

    _, samples = jax.lax.scan(one, key, None, length=trials)
    mean = np.asarray(samples.mean(axis=0))
    # 3-sigma Monte-Carlo band on a Bernoulli mean (p<=1 -> var<=0.25)
    tol = 3.0 * 0.5 / np.sqrt(trials) + 0.01
    np.testing.assert_allclose(mean, expect, atol=tol)


def test_fused_and_grid_kernels_bit_identical():
    """§Perf L2: the fused single-block kernel (shipped in the AOT
    artifacts) must equal the per-head-grid kernel and the oracle."""
    q, k, v, us, ua = _setup(5, 6, 16, 16)
    fused = ssa_attention_step(q, k, v, us, ua, fused=True)
    grid = ssa_attention_step(q, k, v, us, ua, fused=False)
    oracle = ref.ssa_attention_step(q, k, v, us, ua)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(grid))
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(oracle))


def test_shape_validation():
    q, k, v, us, ua = _setup(0, 2, 8, 16)
    with pytest.raises(ValueError):
        ssa_attention_step(q, k, v, us[:, :4, :], ua)
    with pytest.raises(ValueError):
        ssa_attention_step(q, k, v, us, ua[:, :, :4])
    with pytest.raises(ValueError):
        ssa_attention_step(q, k[:1], v, us, ua)


def test_vmem_estimate_paper_head_fits():
    """ViT-Small head tile (N=64, D_K=48) must fit VMEM with slack."""
    assert vmem_bytes(64, 48) < 16 * 2**20 / 8  # << 1/8 of 16 MiB VMEM


def test_dtype_float32_output():
    q, k, v, us, ua = _setup(3, 1, 4, 8)
    out = ssa_attention_step(q, k, v, us, ua)
    assert out.dtype == jnp.float32
