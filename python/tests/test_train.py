"""Training pipeline: Adam sanity, loss decreases, eval plumbing."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import data, train
from compile.config import TrainConfig, vit_tiny
from compile.layers import init_params


def test_adam_converges_on_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = train.adam_init(params)
    for _ in range(300):
        grads = {"w": 2.0 * params["w"]}
        params, opt = train.adam_update(params, grads, opt, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_weight_decay_shrinks_params():
    params = {"w": jnp.array([1.0])}
    opt = train.adam_init(params)
    zero_grads = {"w": jnp.array([0.0])}
    p2, _ = train.adam_update(params, zero_grads, opt, lr=0.01, weight_decay=1.0)
    assert float(p2["w"][0]) < 1.0


def test_cross_entropy_matches_manual():
    logits = jnp.array([[2.0, 0.0, 0.0]])
    labels = jnp.array([0])
    ce = float(train.cross_entropy(logits, labels))
    manual = -np.log(np.exp(2.0) / (np.exp(2.0) + 2.0))
    assert abs(ce - manual) < 1e-6


def test_short_training_reduces_loss():
    cfg = vit_tiny("ann")
    tcfg = TrainConfig(steps=40, n_train=256, n_test=64, eval_every=1000)
    xtr, ytr = data.make_split(256, seed=0x5A)
    patches = data.patchify(xtr, 4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = train.adam_init(params)
    step = train.make_train_step(cfg, tcfg)
    it = train.batches(patches, ytr, 32, 0)
    losses = []
    for s in range(1, 41):
        bx, by = next(it)
        params, opt, loss = step(params, opt, jnp.asarray(bx), jnp.asarray(by), jnp.uint32(s))
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_evaluate_counts_correctly():
    cfg = vit_tiny("ann")
    xte, yte = data.make_split(64, seed=0xA5)
    patches = data.patchify(xte, 4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    acc = train.evaluate(cfg, params, patches, yte, batch=32)
    assert 0.0 <= acc <= 1.0


def test_batches_cover_dataset():
    x = np.arange(100, dtype=np.float32).reshape(100, 1)
    y = np.arange(100, dtype=np.int32)
    it = train.batches(x, y, 32, seed=1)
    seen = set()
    for _ in range(3):  # one epoch = 3 full batches of 32
        bx, by = next(it)
        assert len(by) == 32
        seen.update(by.tolist())
    assert len(seen) == 96  # 100 - 100%32 remainder dropped
